//! Paged per-request KV cache over a bounded, shard-global block pool
//! (PR 8; incremental decode itself landed in PR 5).
//!
//! Before this rework the cache was per-request contiguous storage with
//! geometric growth, and a context slide threw every cached row away. At
//! millions-of-users scale the KV cache — not the weights — is the memory
//! bill, so storage is now *paged*, vLLM-style:
//!
//! ## Memory model
//!
//! - K/V rows live in fixed-size **blocks** ([`BlockPool::block_rows`]
//!   positions each, spanning every layer), allocated from a bounded
//!   shard-global [`BlockPool`]. A request's [`KvCache`] is a *block
//!   table*: an ordered list of block references plus a front-row offset.
//! - **Pool exhaustion is backpressure, never a panic**: acquiring a
//!   block from a full pool first evicts idle shared blocks, then fails
//!   with a typed [`PoolExhausted`] error that the coordinator maps to
//!   brown-out shedding (`no-panic-serving-path` covers this file).
//!   Every block holds an RAII permit, so dropping a cache — request
//!   retirement, supervisor re-homing, executor death — releases its
//!   blocks exactly once, structurally.
//! - **Shared prefixes**: when sharing is enabled
//!   ([`BlockPool::with_sharing`]), a cache that fills a block while
//!   still 0-anchored (never slid) freezes it into an immutable
//!   [`Arc`]-shared block and publishes it in the pool's prefix registry,
//!   keyed by the token prefix it covers. [`BlockPool::new_cache`] seeds
//!   new requests with the longest registered chain matching their
//!   window, so identical system-prompt/few-shot headers are stored once
//!   per shard and prefilled zero times after the first request. A
//!   writer never mutates a shared block — shared blocks are always full,
//!   and appends target a fresh owned tail block (the copy-on-write
//!   "fork" is the tail allocation).
//! - **Slides re-base instead of invalidating**: at the context cap
//!   [`DecodeState::push_token`] drops the *front cached row*
//!   ([`KvCache::pop_front`]) and keeps every other row. Positional
//!   embedding indices ring over the context window (see
//!   `sim::forward_incremental`): the cache tracks
//!   [`KvCache::positions_seen`], a monotone append counter, and new
//!   tokens embed at `positions_seen % seq_len`. Decode past the cap is
//!   therefore *streaming attention* — O(1) work per token, no
//!   re-prefill — and is pinned block-size-invariant (paged at any block
//!   size produces bit-identical chains) by `tests/decode_equiv.rs`.
//!   Chains that never slide remain bit-identical to full-prefix
//!   recompute, exactly as in PR 5.
//!
//! The cache layout stays model-agnostic (rows of f32): the interpreter
//! (`runtime::sim::forward_incremental`) owns all numerics; this module
//! owns storage, pooling, sharing, and the per-request decode
//! bookkeeping the coordinator's continuous-batching loop steps.

use std::any::Any;
use std::fmt;
use std::sync::Arc;

use anyhow::Result;

use super::sample::Sampler;
use crate::quant::Matrix;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::Mutex;

/// Default positions per block: small enough that short next-token
/// requests waste little, large enough that a 256-token prefill touches
/// the pool only a handful of times. `halo serve --kv-block-size`
/// overrides per deployment.
pub const DEFAULT_BLOCK_ROWS: usize = 16;

/// Typed "the block pool is out of blocks" error, surfaced from
/// [`KvCache::append`] (via block acquisition) after idle-block eviction
/// failed to free capacity. The coordinator downcasts to this to turn
/// cache pressure into brown-out backpressure (shed/retry with
/// [`ShedReason::Brownout`](crate::coordinator::ShedReason::Brownout))
/// instead of a failed request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted {
    /// The pool's configured block bound.
    pub max_blocks: usize,
}

impl fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "KV block pool exhausted ({} blocks allocated, none evictable)",
            self.max_blocks
        )
    }
}

impl std::error::Error for PoolExhausted {}

/// Pool accounting shared by every permit. Kept separate from
/// [`BlockPool`] so permits (inside blocks, inside caches) never form an
/// `Arc` cycle with the pool's registry, which itself holds blocks.
#[derive(Debug, Default)]
struct PoolShared {
    counts: Mutex<PoolCounts>,
    /// Block bound; 0 = unbounded.
    max_blocks: usize,
}

#[derive(Debug, Default)]
struct PoolCounts {
    allocated: usize,
    peak: usize,
}

/// RAII block-capacity permit: holding one *is* owning one pool slot.
/// Dropping it (cache retired, block evicted, executor died mid-step)
/// releases the slot exactly once — re-homing cannot double-free.
#[derive(Debug)]
struct Permit {
    shared: Arc<PoolShared>,
}

impl Permit {
    fn try_new(shared: &Arc<PoolShared>) -> Option<Permit> {
        let mut c = shared.counts.lock().unwrap_or_else(|e| e.into_inner());
        if shared.max_blocks != 0 && c.allocated >= shared.max_blocks {
            return None;
        }
        c.allocated += 1;
        c.peak = c.peak.max(c.allocated);
        drop(c);
        Some(Permit { shared: Arc::clone(shared) })
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        let mut c = self.shared.counts.lock().unwrap_or_else(|e| e.into_inner());
        c.allocated = c.allocated.saturating_sub(1);
    }
}

/// A full, immutable block published for prefix sharing. `k`/`v` hold
/// `n_layers · block_rows · d_model` f32 each; row `(layer, slot)` lives
/// at `(layer · block_rows + slot) · d_model`.
#[derive(Debug)]
struct FrozenBlock {
    k: Vec<f32>,
    v: Vec<f32>,
    _permit: Permit,
}

/// A private, writable block (the tail of a cache's table, or any block
/// of a never-frozen cache).
#[derive(Debug)]
struct OwnedBlock {
    k: Vec<f32>,
    v: Vec<f32>,
    permit: Permit,
}

/// One entry of a request's block table.
#[derive(Debug)]
enum BlockRef {
    /// Immutable, possibly shared with other requests and the registry.
    Shared(Arc<FrozenBlock>),
    /// Private and writable.
    Owned(OwnedBlock),
}

impl BlockRef {
    fn k(&self) -> &[f32] {
        match self {
            BlockRef::Shared(b) => &b.k,
            BlockRef::Owned(b) => &b.k,
        }
    }

    fn v(&self) -> &[f32] {
        match self {
            BlockRef::Shared(b) => &b.v,
            BlockRef::Owned(b) => &b.v,
        }
    }
}

/// One published prefix block: covers `tokens` (0-anchored, a multiple of
/// `block_rows` long); `tokens` disambiguates hash collisions.
#[derive(Debug)]
struct RegEntry {
    hash: u64,
    tokens: Vec<i32>,
    block: Arc<FrozenBlock>,
}

#[derive(Debug, Default)]
struct Registry {
    /// Insertion order; eviction scans newest-first among idle entries so
    /// shallow chain prefixes (the most-shared blocks) outlive deep ones.
    entries: Vec<RegEntry>,
}

fn hash_tokens(tokens: &[i32]) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    tokens.hash(&mut h);
    h.finish()
}

/// Point-in-time [`BlockPool`] statistics, exported per shard through
/// [`BatchExecutor::kv_pool_stats`](crate::coordinator::BatchExecutor::kv_pool_stats)
/// into serving [`Metrics`](crate::coordinator::Metrics).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Blocks currently allocated (owned + frozen, including
    /// registry-held).
    pub blocks_in_use: usize,
    /// High-water mark of `blocks_in_use`.
    pub blocks_peak: usize,
    /// Configured bound (0 = unbounded).
    pub max_blocks: usize,
    /// Positions per block.
    pub block_rows: usize,
    /// Blocks seeded into new caches from the prefix registry.
    pub shared_hits: u64,
    /// [`BlockPool::new_cache`] calls that consulted the registry.
    pub prefix_lookups: u64,
    /// Idle registry blocks dropped to make room under pressure.
    pub evictions: u64,
    /// Block acquisitions refused after eviction found nothing idle
    /// (each surfaces as a [`PoolExhausted`] error upstream).
    pub refusals: u64,
    /// Prefix chains currently published in the registry.
    pub registry_entries: usize,
}

/// Bounded, shard-global pool of fixed-size K/V blocks plus the
/// shared-prefix registry. One pool per shard (created outside the
/// executor factory so the prefix cache survives supervisor respawns);
/// every request cache on the shard allocates from it. See the module
/// docs for the memory model.
#[derive(Debug)]
pub struct BlockPool {
    n_layers: usize,
    d: usize,
    block_rows: usize,
    shared: Arc<PoolShared>,
    registry: Mutex<Registry>,
    /// Max published prefix entries; 0 = sharing disabled.
    registry_cap: usize,
    evictions: AtomicU64,
    shared_hits: AtomicU64,
    prefix_lookups: AtomicU64,
    refusals: AtomicU64,
}

impl BlockPool {
    /// A pool for a model with `n_layers` layers of width `d_model`,
    /// `block_rows` positions per block, bounded at `max_blocks` blocks
    /// (0 = unbounded). Sharing starts disabled; see
    /// [`BlockPool::with_sharing`].
    pub fn new(n_layers: usize, d_model: usize, block_rows: usize, max_blocks: usize) -> Self {
        Self {
            n_layers,
            d: d_model,
            block_rows: block_rows.max(1),
            shared: Arc::new(PoolShared { counts: Mutex::default(), max_blocks }),
            registry: Mutex::default(),
            registry_cap: 0,
            evictions: AtomicU64::new(0),
            shared_hits: AtomicU64::new(0),
            prefix_lookups: AtomicU64::new(0),
            refusals: AtomicU64::new(0),
        }
    }

    /// Enable shared-prefix reuse with at most `registry_cap` published
    /// prefix blocks (idle entries beyond the cap are evicted
    /// newest-first; entries pinned by live caches never are).
    pub fn with_sharing(mut self, registry_cap: usize) -> Self {
        self.registry_cap = registry_cap;
        self
    }

    /// Positions per block.
    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    /// Configured block bound (0 = unbounded).
    pub fn max_blocks(&self) -> usize {
        self.shared.max_blocks
    }

    /// Point-in-time statistics (occupancy, sharing, eviction counters).
    pub fn stats(&self) -> PoolStats {
        let (blocks_in_use, blocks_peak) = {
            let c = self.shared.counts.lock().unwrap_or_else(|e| e.into_inner());
            (c.allocated, c.peak)
        };
        let registry_entries =
            self.registry.lock().unwrap_or_else(|e| e.into_inner()).entries.len();
        PoolStats {
            blocks_in_use,
            blocks_peak,
            max_blocks: self.shared.max_blocks,
            block_rows: self.block_rows,
            shared_hits: self.shared_hits.load(Ordering::Relaxed),
            prefix_lookups: self.prefix_lookups.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            refusals: self.refusals.load(Ordering::Relaxed),
            registry_entries,
        }
    }

    /// A cache for one request whose 0-anchored context window starts
    /// with `window`, seeded with the longest registered shared-prefix
    /// chain strictly shorter than the window (at least the final window
    /// position is always left uncached — its logits must be computed to
    /// decode the next token).
    pub fn new_cache(self: &Arc<Self>, window: &[i32]) -> KvCache {
        let chain = self.match_prefix(window);
        let len = chain.len() * self.block_rows;
        KvCache {
            pool: Arc::clone(self),
            blocks: chain.into_iter().map(BlockRef::Shared).collect(),
            layer_rows: vec![0; self.n_layers],
            len,
            start: 0,
            positions_seen: len,
            token_history: if self.registry_cap > 0 { window[..len].to_vec() } else { Vec::new() },
            share_eligible: self.registry_cap > 0,
            shared_rows: len,
        }
    }

    /// Longest registered chain of full blocks covering a proper prefix
    /// of `window` (token-verified, not just hash-matched).
    fn match_prefix(&self, window: &[i32]) -> Vec<Arc<FrozenBlock>> {
        if self.registry_cap == 0 || window.len() <= self.block_rows {
            return Vec::new();
        }
        self.prefix_lookups.fetch_add(1, Ordering::Relaxed);
        let mut chain = Vec::new();
        let reg = self.registry.lock().unwrap_or_else(|e| e.into_inner());
        let mut k = self.block_rows;
        // Strictly `<`: never seed the whole window (see `new_cache`).
        while k < window.len() {
            let want = &window[..k];
            let h = hash_tokens(want);
            match reg.entries.iter().rev().find(|e| e.hash == h && e.tokens == want) {
                Some(e) => chain.push(Arc::clone(&e.block)),
                None => break,
            }
            k += self.block_rows;
        }
        drop(reg);
        if !chain.is_empty() {
            self.shared_hits.fetch_add(chain.len() as u64, Ordering::Relaxed);
        }
        chain
    }

    /// Acquire one zeroed writable block, evicting idle registry blocks
    /// under pressure. Errors with [`PoolExhausted`] when the pool is at
    /// its bound and nothing is evictable. The `kvcache.grow` failpoint
    /// arms here — exactly the allocation edge it modeled pre-paging.
    fn acquire_block(&self) -> Result<OwnedBlock> {
        crate::util::failpoint::check(crate::util::failpoint::sites::KVCACHE_GROW)?;
        // Bounded retry: every iteration either acquires or evicts at
        // least one registry entry, and the registry is finite.
        loop {
            if let Some(permit) = Permit::try_new(&self.shared) {
                let n = self.n_layers * self.block_rows * self.d;
                return Ok(OwnedBlock { k: vec![0.0; n], v: vec![0.0; n], permit });
            }
            if self.evict_one_idle() == 0 {
                self.refusals.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow::Error::new(PoolExhausted {
                    max_blocks: self.shared.max_blocks,
                }));
            }
        }
    }

    /// Drop the newest idle registry entry (strong count 1 ⇒ only the
    /// registry holds it; a live cache sharing a block also pins every
    /// shallower block of its chain, so newest-first never strands a
    /// reachable chain prefix). The freed `Arc` is dropped *outside* the
    /// registry lock — its permit re-enters the pool counts mutex.
    fn evict_one_idle(&self) -> usize {
        let evicted = {
            let mut reg = self.registry.lock().unwrap_or_else(|e| e.into_inner());
            match reg.entries.iter().rposition(|e| Arc::strong_count(&e.block) == 1) {
                Some(i) => Some(reg.entries.remove(i)),
                None => None,
            }
        };
        match evicted {
            Some(entry) => {
                drop(entry);
                self.evictions.fetch_add(1, Ordering::Relaxed);
                1
            }
            None => 0,
        }
    }

    /// Publish a frozen block covering the 0-anchored `tokens` prefix.
    /// Over-cap idle entries are evicted newest-first; entries pinned by
    /// live caches may keep the registry transiently over cap (they are
    /// already bounded by the pool's block bound).
    fn register(&self, tokens: &[i32], block: &Arc<FrozenBlock>) {
        if self.registry_cap == 0 {
            return;
        }
        let h = hash_tokens(tokens);
        let dropped = {
            let mut reg = self.registry.lock().unwrap_or_else(|e| e.into_inner());
            if reg.entries.iter().any(|e| e.hash == h && e.tokens == tokens) {
                return;
            }
            reg.entries.push(RegEntry {
                hash: h,
                tokens: tokens.to_vec(),
                block: Arc::clone(block),
            });
            let mut dropped = Vec::new();
            while reg.entries.len() > self.registry_cap {
                match reg.entries.iter().rposition(|e| Arc::strong_count(&e.block) == 1) {
                    Some(i) => dropped.push(reg.entries.remove(i)),
                    None => break,
                }
            }
            dropped
        };
        if !dropped.is_empty() {
            self.evictions.fetch_add(dropped.len() as u64, Ordering::Relaxed);
            drop(dropped);
        }
    }
}

/// Read view of one layer's cached K/V rows through a cache's block
/// table — the indexing adapter `sim::attention_cached` reads rows
/// through (replacing PR 5's contiguous `LayerKv`). Row `r` is the
/// layer's `r`-th *live* row (committed + staged), after any slide
/// re-basing.
#[derive(Debug, Clone, Copy)]
pub struct LayerView<'a> {
    cache: &'a KvCache,
    layer: usize,
}

impl LayerView<'_> {
    /// Live rows (committed + staged) for this layer.
    pub fn rows(&self) -> usize {
        self.cache.len + self.cache.layer_rows[self.layer]
    }

    /// Cached key row `r`.
    pub fn k_row(&self, r: usize) -> &[f32] {
        let (bi, off) = self.cache.locate(self.layer, r);
        &self.cache.blocks[bi].k()[off..off + self.cache.pool.d]
    }

    /// Cached value row `r`.
    pub fn v_row(&self, r: usize) -> &[f32] {
        let (bi, off) = self.cache.locate(self.layer, r);
        &self.cache.blocks[bi].v()[off..off + self.cache.pool.d]
    }
}

/// Per-request paged KV cache: a block table over a [`BlockPool`] plus
/// decode bookkeeping. See the module docs for the memory model.
#[derive(Debug)]
pub struct KvCache {
    pool: Arc<BlockPool>,
    blocks: Vec<BlockRef>,
    /// Staged (appended, uncommitted) row count per layer.
    layer_rows: Vec<usize>,
    /// Committed positions (logical rows) across every layer.
    len: usize,
    /// Front-row offset inside `blocks[0]` after slides.
    start: usize,
    /// Monotone count of positions ever committed — the ring-position
    /// basis for positional embeddings (never decremented by slides).
    positions_seen: usize,
    /// Tokens behind rows `0..len`, kept only while `share_eligible`.
    token_history: Vec<i32>,
    /// Still 0-anchored and never slid, with sharing on: full blocks
    /// freeze + publish at commit.
    share_eligible: bool,
    /// Rows seeded from the shared-prefix registry at construction.
    shared_rows: usize,
}

impl KvCache {
    /// Empty standalone cache (private unbounded pool, sharing off) —
    /// the PR 5-compatible constructor for single-request decode paths
    /// and tests. Serving executors use [`BlockPool::new_cache`] instead
    /// so requests share one bounded pool per shard.
    pub fn new(n_layers: usize, d_model: usize) -> Self {
        Arc::new(BlockPool::new(n_layers, d_model, DEFAULT_BLOCK_ROWS, 0)).new_cache(&[])
    }

    /// Number of transformer layers this cache covers.
    pub fn n_layers(&self) -> usize {
        self.layer_rows.len()
    }

    /// Model width (columns of every cached row).
    pub fn d_model(&self) -> usize {
        self.pool.d
    }

    /// Positions fully cached across every layer (committed by
    /// [`KvCache::commit`] at the end of a successful step).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no position is cached.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when no layer holds staged (uncommitted) rows. An errored-out
    /// incremental step can leave a partial append; such a cache must be
    /// [`KvCache::clear`]ed (re-prefilled), never resumed.
    pub fn is_consistent(&self) -> bool {
        self.layer_rows.iter().all(|&r| r == 0)
    }

    /// Positions the current block table can hold without acquiring
    /// another block (includes rows consumed by the slide offset).
    pub fn capacity_rows(&self) -> usize {
        self.blocks.len() * self.pool.block_rows
    }

    /// Blocks currently referenced by this cache's table.
    pub fn blocks_in_table(&self) -> usize {
        self.blocks.len()
    }

    /// Heap bytes referenced by this cache's block table (K + V, f32,
    /// all layers). Shared blocks count fully here even though the pool
    /// stores them once across requests.
    pub fn reserved_bytes(&self) -> usize {
        self.blocks.len()
            * 2
            * self.pool.n_layers
            * self.pool.block_rows
            * self.pool.d
            * std::mem::size_of::<f32>()
    }

    /// Total positions ever committed (monotone across slides) — the
    /// absolute position of the next appended token, which the
    /// interpreter rings over the model's context window for positional
    /// embedding. Equals [`KvCache::len`] until the first slide.
    pub fn positions_seen(&self) -> usize {
        self.positions_seen
    }

    /// Rows this cache was seeded with from the shared-prefix registry.
    pub fn shared_rows(&self) -> usize {
        self.shared_rows
    }

    /// Read access to one layer's cached rows.
    pub fn layer(&self, l: usize) -> LayerView<'_> {
        LayerView { cache: self, layer: l }
    }

    /// Block index + element offset of `(layer, row)` for width-`d`
    /// slicing.
    fn locate(&self, layer: usize, row: usize) -> (usize, usize) {
        let bs = self.pool.block_rows;
        let phys = self.start + row;
        (phys / bs, (layer * bs + phys % bs) * self.pool.d)
    }

    /// Append freshly projected K/V rows to `layer`, acquiring pool
    /// blocks as the table grows. The interpreter calls this once per
    /// layer per step, then [`KvCache::commit`]s. A [`PoolExhausted`]
    /// error leaves previously staged rows in place; the caller clears
    /// and retries/sheds (see `is_consistent`).
    pub fn append(&mut self, layer: usize, k_rows: &Matrix, v_rows: &Matrix) -> Result<()> {
        anyhow::ensure!(
            layer < self.layer_rows.len(),
            "KV append to layer {layer} of a {}-layer cache",
            self.layer_rows.len()
        );
        anyhow::ensure!(
            k_rows.cols == self.pool.d && v_rows.cols == self.pool.d,
            "KV rows of width {}/{} appended to a d_model={} cache",
            k_rows.cols,
            v_rows.cols,
            self.pool.d
        );
        anyhow::ensure!(
            k_rows.rows == v_rows.rows,
            "K/V row-count mismatch: {} vs {}",
            k_rows.rows,
            v_rows.rows
        );
        let (bs, d) = (self.pool.block_rows, self.pool.d);
        for j in 0..k_rows.rows {
            let phys = self.start + self.len + self.layer_rows[layer] + j;
            let bi = phys / bs;
            while bi >= self.blocks.len() {
                let block = self.pool.acquire_block()?;
                self.blocks.push(BlockRef::Owned(block));
            }
            let off = (layer * bs + phys % bs) * d;
            match &mut self.blocks[bi] {
                BlockRef::Owned(b) => {
                    b.k[off..off + d].copy_from_slice(&k_rows.data[j * d..(j + 1) * d]);
                    b.v[off..off + d].copy_from_slice(&v_rows.data[j * d..(j + 1) * d]);
                }
                BlockRef::Shared(_) => anyhow::bail!(
                    "KV append targets a shared (frozen) block at row {} — paging invariant \
                     violated (shared blocks are always full)",
                    self.len + self.layer_rows[layer] + j
                ),
            }
        }
        self.layer_rows[layer] += k_rows.rows;
        Ok(())
    }

    /// Mark the staged rows for `tokens` fully cached, verifying every
    /// layer actually received them (a failed step that appended to only
    /// some layers is detected here and at the next step's consistency
    /// check). The token values extend the cache's 0-anchored history so
    /// newly filled blocks can be frozen + published for prefix sharing.
    pub fn commit(&mut self, tokens: &[i32]) -> Result<()> {
        let n = tokens.len();
        anyhow::ensure!(
            self.layer_rows.iter().all(|&r| r == n),
            "partial KV append: committing {n} positions but staged layer rows are {:?}",
            self.layer_rows
        );
        self.len += n;
        self.positions_seen += n;
        for r in self.layer_rows.iter_mut() {
            *r = 0;
        }
        if self.share_eligible {
            self.token_history.extend_from_slice(tokens);
            self.publish_full_blocks();
        }
        Ok(())
    }

    /// Freeze every fully committed owned block (0-anchored caches only:
    /// `start == 0`) into an immutable shared block and publish it under
    /// the token prefix it covers.
    fn publish_full_blocks(&mut self) {
        let bs = self.pool.block_rows;
        let full = self.len / bs;
        for bi in 0..full.min(self.blocks.len()) {
            if !matches!(self.blocks[bi], BlockRef::Owned(_)) {
                continue;
            }
            let BlockRef::Owned(b) = self.blocks.remove(bi) else { continue };
            let arc = Arc::new(FrozenBlock { k: b.k, v: b.v, _permit: b.permit });
            if self.token_history.len() >= (bi + 1) * bs {
                self.pool.register(&self.token_history[..(bi + 1) * bs], &arc);
            }
            self.blocks.insert(bi, BlockRef::Shared(arc));
        }
    }

    /// Slide re-basing: drop the front cached row, keeping every other
    /// row live (no re-prefill). The front block is released back to the
    /// pool once the offset crosses it. A slid cache is no longer
    /// 0-anchored, so it stops publishing prefix blocks. No-op on an
    /// empty cache (a cleared cache re-prefills anyway).
    pub fn pop_front(&mut self) {
        if self.len == 0 {
            return;
        }
        self.len -= 1;
        self.start += 1;
        self.share_eligible = false;
        self.token_history = Vec::new();
        if self.start >= self.pool.block_rows && !self.blocks.is_empty() {
            drop(self.blocks.remove(0));
            self.start -= self.pool.block_rows;
        }
    }

    /// Speculative rollback: drop every committed position past
    /// `new_len`, releasing now-unreferenced tail blocks back to the
    /// pool — truncate, don't re-prefill. `positions_seen` rewinds with
    /// the dropped rows so the ring positions of re-appended tokens are
    /// bit-identical to a chain that never speculated past the accept
    /// point.
    ///
    /// If the new tail block was frozen for prefix sharing while the
    /// rejected rows were still committed (a batched verify pass can
    /// fill and publish a block that the rollback then re-opens), the
    /// kept rows are copied out of the frozen block into a fresh owned
    /// block (copy-on-write fork, mirroring `BlockPool::new_cache`'s
    /// partial-tail handling) so subsequent appends stay legal. That
    /// fork is the only path that can fail, with the pool's typed
    /// [`PoolExhausted`] backpressure.
    pub fn truncate_to(&mut self, new_len: usize) -> Result<()> {
        anyhow::ensure!(
            self.is_consistent(),
            "KV truncate of a cache with staged rows: {:?}",
            self.layer_rows
        );
        anyhow::ensure!(
            new_len <= self.len,
            "KV truncate to {new_len} of a {}-position cache",
            self.len
        );
        let dropped = self.len - new_len;
        if dropped == 0 {
            return Ok(());
        }
        self.len = new_len;
        self.positions_seen -= dropped;
        self.shared_rows = self.shared_rows.min(new_len);
        if self.share_eligible {
            self.token_history.truncate(new_len);
        }
        // Release tail blocks past the last live row. Shared tails stay
        // registered in the pool; dropping our reference is enough.
        let bs = self.pool.block_rows;
        let live_rows = self.start + self.len;
        let need = live_rows.div_ceil(bs);
        self.blocks.truncate(need);
        // Re-open a partially live frozen tail so appends can land in it.
        if live_rows % bs != 0 && need > 0 {
            if let BlockRef::Shared(arc) = &self.blocks[need - 1] {
                let mut owned = self.pool.acquire_block()?;
                owned.k.copy_from_slice(&arc.k);
                owned.v.copy_from_slice(&arc.v);
                self.blocks[need - 1] = BlockRef::Owned(owned);
            }
        }
        Ok(())
    }

    /// Invalidate every cached position, releasing all blocks back to
    /// the pool. Used after failed steps (partial appends) and by retry
    /// restarts; a cleared cache behaves exactly like a fresh one
    /// (positions re-anchor at 0, sharing eligibility resets), keeping
    /// retried decodes bit-identical to first attempts.
    pub fn clear(&mut self) {
        self.blocks.clear();
        for r in self.layer_rows.iter_mut() {
            *r = 0;
        }
        self.len = 0;
        self.start = 0;
        self.positions_seen = 0;
        self.shared_rows = 0;
        self.token_history.clear();
        self.share_eligible = self.pool.registry_cap > 0;
    }
}

/// Decode progress for one in-flight request: the sliding context
/// window, the tokens generated so far, and (when the executor supports
/// incremental decode) the request's [`KvCache`].
///
/// The coordinator's continuous-batching loop owns a *set* of these,
/// admitting new states mid-flight and retiring finished ones; an
/// executor's `step` advances each active state by exactly one token.
pub struct DecodeState {
    window: Vec<i32>,
    generated: Vec<i32>,
    max_new: usize,
    seq_cap: usize,
    cache: Option<KvCache>,
    /// Seeded sampler when the request asked for sampled decode; `None`
    /// is greedy argmax.
    sampler: Option<Sampler>,
    /// Executor-private companion state that must travel with the
    /// request through retire / re-homing / drop (the speculative
    /// executor parks the drafter's `DecodeState` here so its KV blocks
    /// release through the same RAII path as the verifier's).
    aux: Option<Box<dyn Any + Send>>,
}

impl fmt::Debug for DecodeState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("DecodeState")
            .field("window", &self.window)
            .field("generated", &self.generated)
            .field("max_new", &self.max_new)
            .field("seq_cap", &self.seq_cap)
            .field("cache", &self.cache)
            .field("sampler", &self.sampler)
            .field("aux", &self.aux.as_ref().map(|_| "<executor aux>"))
            .finish()
    }
}

impl DecodeState {
    /// Oracle-path state (no cache): every step recomputes the whole
    /// window. `seq_cap` is the model context window; the window keeps
    /// the `seq_cap` newest prefix tokens.
    pub fn new(prefix: &[i32], max_new: usize, seq_cap: usize) -> Self {
        let cap = seq_cap.max(1);
        Self {
            window: prefix[prefix.len().saturating_sub(cap)..].to_vec(),
            generated: Vec::new(),
            max_new,
            seq_cap: cap,
            cache: None,
            sampler: None,
            aux: None,
        }
    }

    /// Cached state: steps evaluate only the uncached window suffix. A
    /// pool-seeded `cache` (see [`BlockPool::new_cache`]) may already
    /// cover a shared prefix of the window.
    pub fn with_cache(prefix: &[i32], max_new: usize, seq_cap: usize, cache: KvCache) -> Self {
        let mut s = Self::new(prefix, max_new, seq_cap);
        s.cache = Some(cache);
        s
    }

    /// The current context window (the `seq_cap` newest tokens).
    pub fn window(&self) -> &[i32] {
        &self.window
    }

    /// Tokens generated so far, in order.
    pub fn generated(&self) -> &[i32] {
        &self.generated
    }

    /// This request's decode budget.
    pub fn max_new(&self) -> usize {
        self.max_new
    }

    /// True once `max_new` tokens have been generated (the request
    /// retires from the live set).
    pub fn done(&self) -> bool {
        self.generated.len() >= self.max_new
    }

    /// Whether this state carries a KV cache.
    pub fn has_cache(&self) -> bool {
        self.cache.is_some()
    }

    /// Window positions already covered by the cache (0 without one, or
    /// after a failed step cleared it).
    pub fn cached_rows(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.len())
    }

    /// Mutable cache access for the executor's decode step.
    pub fn cache_mut(&mut self) -> Option<&mut KvCache> {
        self.cache.as_mut()
    }

    /// Attach the request's seeded sampler (shard loop, right after
    /// `begin`). `None` keeps greedy argmax decode.
    pub fn set_sampler(&mut self, sampler: Option<Sampler>) {
        self.sampler = sampler;
    }

    /// Mutable sampler access for the executor's token selection.
    pub fn sampler_mut(&mut self) -> Option<&mut Sampler> {
        self.sampler.as_mut()
    }

    /// Park executor-private companion state on this request (see the
    /// field docs — the speculative drafter's state lives here).
    pub fn set_aux(&mut self, aux: Box<dyn Any + Send>) {
        self.aux = Some(aux);
    }

    /// Detach the executor-private companion state, if any. Executors
    /// take it at the start of a step (avoiding a double borrow against
    /// the window/cache) and put it back at the end.
    pub fn take_aux(&mut self) -> Option<Box<dyn Any + Send>> {
        self.aux.take()
    }

    /// The window suffix the next cached step must evaluate (tokens not
    /// yet covered by the cache) plus the cached-position count — the
    /// shared slicing contract of every cached executor step. Errors when
    /// the cache claims more positions than the window holds (a stale
    /// cache that somehow missed a slide re-base).
    pub fn uncached_suffix(&self) -> Result<(Vec<i32>, usize)> {
        let cached = self.cached_rows();
        anyhow::ensure!(
            cached <= self.window.len(),
            "KV cache covers {cached} positions but the window has {}",
            self.window.len()
        );
        Ok((self.window[cached..].to_vec(), cached))
    }

    /// Record one generated token: appends to the window, sliding
    /// (drop-front) at the context cap. A slide *re-bases* the cache
    /// ([`KvCache::pop_front`]) instead of invalidating it — every
    /// retained row stays live and the next step evaluates exactly one
    /// token (streaming attention; see the module docs).
    pub fn push_token(&mut self, tok: i32) {
        self.generated.push(tok);
        if self.window.len() >= self.seq_cap {
            self.window.remove(0);
            if let Some(c) = &mut self.cache {
                c.pop_front();
            }
        }
        self.window.push(tok);
    }

    /// Drop the `n` newest tokens from the window (and the generated
    /// record), truncating the cache back to the surviving rows via
    /// [`KvCache::truncate_to`] — the speculative-decode rollback (PR 9):
    /// rejected drafter proposals rewind here instead of re-prefilling.
    /// Only valid while none of those `n` pushes slid the window (the
    /// speculative executor bounds its draft length by the context
    /// headroom to guarantee this); a slide in between would have dropped
    /// a front token this rollback cannot restore.
    pub fn rollback(&mut self, n: usize) -> Result<()> {
        if n == 0 {
            return Ok(());
        }
        anyhow::ensure!(
            n <= self.generated.len() && n <= self.window.len(),
            "rollback of {n} tokens from a window of {} ({} generated)",
            self.window.len(),
            self.generated.len()
        );
        self.generated.truncate(self.generated.len() - n);
        self.window.truncate(self.window.len() - n);
        if let Some(c) = &mut self.cache {
            let keep = c.len().min(self.window.len());
            c.truncate_to(keep)?;
        }
        Ok(())
    }

    /// Consume the state, yielding the generated tokens.
    pub fn into_generated(self) -> Vec<i32> {
        self.generated
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(n: usize, d: usize, base: f32) -> Matrix {
        Matrix::from_fn(n, d, |r, c| base + (r * d + c) as f32)
    }

    fn pool(layers: usize, d: usize, bs: usize, max: usize) -> Arc<BlockPool> {
        Arc::new(BlockPool::new(layers, d, bs, max))
    }

    /// Prefill `n` rows (all layers) with deterministic data and commit.
    fn fill(c: &mut KvCache, tokens: &[i32], base: f32) {
        let n = tokens.len();
        for l in 0..c.n_layers() {
            c.append(l, &rows(n, c.d_model(), base + l as f32 * 100.0), &rows(n, c.d_model(), base + 500.0))
                .unwrap();
        }
        c.commit(tokens).unwrap();
    }

    #[test]
    fn append_commit_and_row_access_across_block_boundaries() {
        let p = pool(2, 4, 2, 0); // 2-row blocks force boundary crossings
        let mut c = p.new_cache(&[]);
        assert_eq!(c.len(), 0);
        assert!(c.is_empty() && c.is_consistent());
        for l in 0..2 {
            c.append(l, &rows(3, 4, l as f32 * 100.0), &rows(3, 4, 500.0)).unwrap();
        }
        assert!(!c.is_consistent(), "uncommitted rows must read as inconsistent");
        c.commit(&[7, 8, 9]).unwrap();
        assert_eq!(c.len(), 3);
        assert!(c.is_consistent());
        assert_eq!(c.blocks_in_table(), 2, "3 rows over 2-row blocks = 2 blocks");
        // Row 2 sits in the second block; values must read back exactly.
        assert_eq!(c.layer(1).k_row(2), &[108.0, 109.0, 110.0, 111.0]);
        assert_eq!(c.layer(0).v_row(0), &[500.0, 501.0, 502.0, 503.0]);
        assert_eq!(c.layer(0).rows(), 3);
        assert_eq!(c.reserved_bytes(), 2 * 2 * 2 * 2 * 4 * 4);
    }

    #[test]
    fn commit_detects_partial_appends_and_shapes_are_checked() {
        let p = pool(2, 4, 4, 0);
        let mut c = p.new_cache(&[]);
        assert!(c.append(2, &rows(1, 4, 0.0), &rows(1, 4, 0.0)).is_err());
        assert!(c.append(0, &rows(1, 3, 0.0), &rows(1, 3, 0.0)).is_err());
        assert!(c.append(0, &rows(2, 4, 0.0), &rows(1, 4, 0.0)).is_err());
        c.append(0, &rows(1, 4, 0.0), &rows(1, 4, 0.0)).unwrap();
        assert!(c.commit(&[1]).is_err(), "layer 1 received nothing");
        assert!(!c.is_consistent());
        c.clear();
        assert!(c.is_consistent());
        assert_eq!(c.blocks_in_table(), 0, "clear releases the table");
    }

    #[test]
    fn pool_bound_is_enforced_and_raii_releases() {
        let p = pool(1, 2, 2, 2); // at most 2 blocks = 4 rows
        let mut c = p.new_cache(&[]);
        fill(&mut c, &[1, 2, 3, 4], 0.0);
        assert_eq!(p.stats().blocks_in_use, 2);
        // A fifth row needs a third block: typed refusal, no panic.
        let err = c.append(0, &rows(1, 2, 9.0), &rows(1, 2, 9.0)).unwrap_err();
        assert!(err.downcast_ref::<PoolExhausted>().is_some(), "{err}");
        assert_eq!(p.stats().refusals, 1);
        // The failed step leaves no staged rows behind here (append
        // failed before staging) — and dropping the cache frees all.
        drop(c);
        let s = p.stats();
        assert_eq!(s.blocks_in_use, 0, "RAII permits must release on drop");
        assert_eq!(s.blocks_peak, 2);
    }

    #[test]
    fn slide_rebases_without_reallocating_blocks() {
        // The PR 8 bugfix regression: a slide at the context cap drops
        // only the front row — no clear, no re-prefill, bounded blocks.
        let p = pool(1, 2, 2, 0);
        let mut c = p.new_cache(&[]);
        fill(&mut c, &[10, 11, 12, 13], 0.0); // 4 rows = 2 full blocks
        let row1 = c.layer(0).k_row(1).to_vec();
        let peak_before = p.stats().blocks_peak;
        c.pop_front();
        assert_eq!(c.len(), 3, "pop_front drops exactly one row");
        assert_eq!(
            c.layer(0).k_row(0),
            &row1[..],
            "remaining rows re-base (old row 1 becomes row 0)"
        );
        c.pop_front(); // start crosses the block edge: front block freed
        assert_eq!(c.len(), 2);
        assert_eq!(c.blocks_in_table(), 1, "front block released after offset crosses it");
        assert_eq!(p.stats().blocks_in_use, 1);
        // Appending after slides reuses the ring: one new block max.
        fill(&mut c, &[14, 15], 50.0);
        assert_eq!(c.len(), 4);
        assert!(p.stats().blocks_peak <= peak_before.max(2) + 1);
        assert_eq!(c.positions_seen(), 6, "positions_seen is monotone across slides");
    }

    #[test]
    fn shared_prefix_seeding_hits_and_verifies_tokens() {
        let p = Arc::new(BlockPool::new(1, 2, 2, 0).with_sharing(16));
        let header: Vec<i32> = vec![5, 6, 7, 8]; // two full blocks
        let mut a = p.new_cache(&header);
        assert_eq!(a.shared_rows(), 0, "empty registry seeds nothing");
        fill(&mut a, &header, 1.0);
        assert_eq!(p.stats().registry_entries, 2, "full blocks publish at commit");

        // Same header, longer window: seeds both published blocks.
        let window: Vec<i32> = vec![5, 6, 7, 8, 9];
        let b = p.new_cache(&window);
        assert_eq!(b.shared_rows(), 4);
        assert_eq!(b.len(), 4);
        assert_eq!(b.layer(0).k_row(1), a.layer(0).k_row(1), "seeded rows are the same memory");
        // A window equal to the published prefix must keep one row
        // uncached (its logits drive the next token).
        let c = p.new_cache(&header);
        assert_eq!(c.len(), 2, "never seed the whole window");
        // Divergent tokens must not match even on hash collisions.
        let d = p.new_cache(&[5, 6, 99, 100, 101]);
        assert_eq!(d.shared_rows(), 2, "only the first block matches");
        let s = p.stats();
        // Lookups: caches a, b, c, d. Hits: b seeded 2 blocks, c and d
        // one each.
        assert_eq!((s.prefix_lookups, s.shared_hits), (4, 4), "{s:?}");
    }

    #[test]
    fn pool_pressure_evicts_idle_registry_blocks() {
        let p = Arc::new(BlockPool::new(1, 2, 2, 2).with_sharing(16));
        let mut a = p.new_cache(&[1, 2, 3, 4]);
        fill(&mut a, &[1, 2], 0.0); // one full block, published
        drop(a); // registry now holds the only reference
        assert_eq!(p.stats().blocks_in_use, 1);
        assert_eq!(p.stats().registry_entries, 1);
        // A 4-row prefill needs 2 blocks: eviction must free the idle one.
        let mut b = p.new_cache(&[9, 9, 9, 9, 9]);
        fill(&mut b, &[9, 9, 9, 9], 2.0);
        let s = p.stats();
        assert_eq!(s.evictions, 1, "idle registry block evicted under pressure");
        assert_eq!(s.blocks_in_use, 2);
        assert_eq!(s.refusals, 0);
    }

    #[test]
    fn slid_caches_stop_publishing() {
        let p = Arc::new(BlockPool::new(1, 2, 2, 0).with_sharing(16));
        let mut c = p.new_cache(&[1, 2, 3]);
        fill(&mut c, &[1, 2, 3], 0.0);
        let before = p.stats().registry_entries;
        c.pop_front();
        fill(&mut c, &[4, 5], 9.0);
        assert_eq!(
            p.stats().registry_entries,
            before,
            "a slid cache is not 0-anchored and must not publish"
        );
    }

    #[test]
    fn clear_resets_to_a_fresh_cache() {
        let p = pool(1, 2, 2, 0);
        let mut c = p.new_cache(&[]);
        fill(&mut c, &[1, 2, 3], 0.0);
        c.pop_front();
        c.clear();
        assert_eq!((c.len(), c.positions_seen(), c.blocks_in_table()), (0, 0, 0));
        assert_eq!(p.stats().blocks_in_use, 0);
        // Usable again, re-anchored at position 0.
        fill(&mut c, &[7], 1.0);
        assert_eq!(c.len(), 1);
        assert_eq!(c.positions_seen(), 1);
    }

    #[test]
    fn truncate_releases_tail_blocks_and_rewinds_positions() {
        let p = pool(2, 4, 2, 0);
        let mut c = p.new_cache(&[]);
        fill(&mut c, &[1, 2, 3, 4, 5], 0.0);
        assert_eq!((c.len(), c.blocks_in_table()), (5, 3));
        assert_eq!(p.stats().blocks_in_use, 3);

        c.truncate_to(2).unwrap();
        assert_eq!((c.len(), c.positions_seen()), (2, 2));
        assert_eq!(c.blocks_in_table(), 1, "rows 0..2 fit one 2-row block");
        assert_eq!(p.stats().blocks_in_use, 1, "tail blocks released to the pool");
        // Kept rows are untouched.
        assert_eq!(c.layer(0).k_row(1), &[4.0, 5.0, 6.0, 7.0]);

        // Re-appending lands at the rewound ring positions: the cache is
        // indistinguishable from one that only ever committed 2 rows.
        fill(&mut c, &[6, 7], 9.0);
        assert_eq!((c.len(), c.positions_seen()), (4, 4));
        assert_eq!(c.layer(0).k_row(2), &[9.0, 10.0, 11.0, 12.0]);

        // Truncating to the current length is a no-op; past it errors.
        c.truncate_to(4).unwrap();
        assert_eq!(c.len(), 4);
        assert!(c.truncate_to(5).is_err());
        // A cache with staged rows must refuse to truncate.
        c.append(0, &rows(1, 4, 0.0), &rows(1, 4, 0.0)).unwrap();
        assert!(c.truncate_to(1).is_err());
    }

    #[test]
    fn truncate_reopens_frozen_tail_block_for_appends() {
        let p = Arc::new(BlockPool::new(1, 2, 2, 0).with_sharing(16));
        let mut c = p.new_cache(&[]);
        fill(&mut c, &[1, 2, 3, 4], 0.0);
        assert_eq!(p.stats().registry_entries, 2, "both full blocks froze");

        // Roll back into the second (frozen) block: the kept row must be
        // forked into a fresh owned block so the next append is legal.
        c.truncate_to(3).unwrap();
        assert_eq!(c.layer(0).k_row(2), &[4.0, 5.0], "kept row survives the fork");
        fill(&mut c, &[9], 7.0);
        assert_eq!(c.len(), 4);
        assert_eq!(c.layer(0).k_row(3), &[7.0, 8.0]);
        assert_eq!(
            c.layer(0).k_row(2),
            &[4.0, 5.0],
            "fork is copy-on-write: old row intact next to the new one"
        );
        // The registry still serves the original (pre-rollback) prefix.
        let seeded = p.new_cache(&[1, 2, 3, 4, 5]);
        assert_eq!(seeded.shared_rows(), 4);

        // Divergent history republishes under the new tokens.
        assert_eq!(p.stats().registry_entries, 3, "re-filled fork published anew");
        let seeded2 = p.new_cache(&[1, 2, 3, 9, 5]);
        assert_eq!(seeded2.shared_rows(), 4, "post-rollback history is shareable");
    }

    #[test]
    fn truncate_after_slide_accounts_start_offset() {
        let p = pool(1, 2, 2, 0);
        let mut c = p.new_cache(&[]);
        fill(&mut c, &[1, 2, 3, 4], 0.0);
        c.pop_front(); // len 3, start 1 — block 0 still referenced
        assert_eq!((c.len(), c.blocks_in_table()), (3, 2));
        c.truncate_to(1).unwrap();
        // Live physical rows = start(1) + len(1) = 2 → one block.
        assert_eq!((c.len(), c.blocks_in_table()), (1, 1));
        assert_eq!(c.positions_seen(), 2, "4 committed - 2 truncated");
        assert_eq!(c.layer(0).k_row(0), &[2.0, 3.0], "row 0 is the post-slide front");
        fill(&mut c, &[8], 5.0);
        assert_eq!(c.layer(0).k_row(1), &[5.0, 6.0]);
        assert_eq!(p.stats().blocks_in_use, 2);
    }

    #[test]
    fn decode_state_slide_keeps_cache_live() {
        // Mirrors the serving decode contract: keep the newest `cap`
        // prefix tokens, slide at the cap, re-base (never clear).
        let mut s = DecodeState::with_cache(&[1, 2, 3, 4, 5], 3, 4, KvCache::new(1, 2));
        assert_eq!(s.window(), &[2, 3, 4, 5]);
        assert!(!s.done());
        assert_eq!(s.cached_rows(), 0);
        // Simulate a prefill having cached the whole window.
        {
            let c = s.cache_mut().unwrap();
            c.append(0, &rows(4, 2, 0.0), &rows(4, 2, 0.0)).unwrap();
            c.commit(&[2, 3, 4, 5]).unwrap();
        }
        assert_eq!(s.cached_rows(), 4);
        s.push_token(9); // at cap: slides and re-bases
        assert_eq!(s.window(), &[3, 4, 5, 9]);
        assert_eq!(s.generated(), &[9]);
        assert_eq!(s.cached_rows(), 3, "slide drops exactly the front row");
        assert_eq!(s.uncached_suffix().unwrap(), (vec![9], 3));
        s.push_token(8);
        s.push_token(7);
        assert!(s.done());
        assert_eq!(s.into_generated(), vec![9, 8, 7]);
    }

    #[test]
    fn decode_state_rollback_rewinds_window_generated_and_cache() {
        // The speculative drafter's rewind: push proposals, evaluate some
        // of them (cache rows), then roll the rejected tail back.
        let p = pool(1, 2, 2, 0);
        let mut s = DecodeState::with_cache(&[1, 2], 8, 16, p.new_cache(&[]));
        {
            let c = s.cache_mut().unwrap();
            c.append(0, &rows(2, 2, 0.0), &rows(2, 2, 0.0)).unwrap();
            c.commit(&[1, 2]).unwrap();
        }
        s.push_token(10);
        s.push_token(11);
        s.push_token(12);
        // Evaluate the first pushed token only: cache covers 3 rows.
        {
            let c = s.cache_mut().unwrap();
            c.append(0, &rows(1, 2, 9.0), &rows(1, 2, 9.0)).unwrap();
            c.commit(&[10]).unwrap();
        }
        assert_eq!(s.window(), &[1, 2, 10, 11, 12]);
        assert_eq!(s.cached_rows(), 3);
        s.rollback(2).unwrap();
        assert_eq!(s.window(), &[1, 2, 10]);
        assert_eq!(s.generated(), &[10]);
        assert_eq!(s.cached_rows(), 3, "rows for surviving tokens stay live");
        s.rollback(1).unwrap();
        assert_eq!(s.window(), &[1, 2]);
        assert!(s.generated().is_empty());
        assert_eq!(s.cached_rows(), 2, "cache truncates with the window");
        assert_eq!(s.uncached_suffix().unwrap(), (vec![], 2));
        assert!(s.rollback(1).is_err(), "cannot roll back past the generated record");
        assert!(s.rollback(0).is_ok(), "zero rollback is a no-op");
    }

    #[test]
    fn decode_state_short_prefix_grows_before_sliding() {
        let mut s = DecodeState::new(&[1], 4, 4);
        assert!(!s.has_cache());
        s.push_token(2);
        s.push_token(3);
        s.push_token(4);
        assert_eq!(s.window(), &[1, 2, 3, 4]);
        s.push_token(5); // first slide only once the window is full
        assert_eq!(s.window(), &[2, 3, 4, 5]);
        assert!(s.done());
    }

    #[test]
    fn empty_prefix_and_zero_budget() {
        let s = DecodeState::new(&[], 0, 8);
        assert!(s.window().is_empty());
        assert!(s.done(), "max_new = 0 is done before any step");
    }
}
