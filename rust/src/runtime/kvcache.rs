//! Per-request KV cache + decode state for incremental autoregressive
//! decode (PR 5).
//!
//! Before this module, every decode step re-ran the *entire* prefix
//! through the forward interpreter — O(S²) work per generated token.
//! The KV cache stores each layer's key/value projections for every
//! position already processed, so a step only evaluates the window
//! suffix that is not yet cached (normally exactly one token) and
//! attends it against the cached rows.
//!
//! ## Memory model
//!
//! - One [`KvCache`] per in-flight request (caches are never shared:
//!   different requests have different prefixes, and a request's cache
//!   dies with its [`DecodeState`] when the request retires).
//! - Per layer, K and V are each a contiguous row-major `(positions,
//!   d_model)` f32 block. Capacity grows geometrically: the first
//!   append reserves [`INITIAL_CAP_ROWS`] positions, and each
//!   exhaustion doubles, so a decode that runs to the model's context
//!   window performs O(log S) reallocations and the differential suite
//!   can place a prefix across a growth boundary deliberately.
//! - Bytes per request ≈ `2 · n_layers · capacity_rows · d_model · 4`
//!   ([`KvCache::reserved_bytes`]); capacity is retained across
//!   [`KvCache::clear`] so a slide-induced re-prefill reuses the
//!   allocation instead of re-growing from scratch.
//! - Sliding the context window (drop-front at `seq_len`) shifts every
//!   absolute position — positional embeddings make every cached row
//!   stale — so [`DecodeState::push_token`] *clears* the cache on a
//!   slide and the next step re-prefills the shifted window. That is
//!   exactly the recompute the oracle path performs at the cap, which
//!   keeps cached and uncached decode bit-identical there too.
//!
//! The cache layout is deliberately model-agnostic (rows of f32): the
//! interpreter (`runtime::sim::forward_incremental`) owns all numerics;
//! this module owns only storage, growth, and the per-request decode
//! bookkeeping that the coordinator's continuous-batching loop steps.

use anyhow::Result;

use crate::quant::Matrix;

/// Positions reserved by a layer's first append; capacity doubles from
/// here. Small enough that short next-token requests stay cheap, large
/// enough that a 256-token prefill performs only a handful of growths.
pub const INITIAL_CAP_ROWS: usize = 16;

/// One layer's cached key/value projections: two contiguous row-major
/// `(rows, d_model)` f32 blocks with explicitly managed row capacity.
#[derive(Debug, Clone)]
pub struct LayerKv {
    k: Vec<f32>,
    v: Vec<f32>,
    d: usize,
    rows: usize,
    cap_rows: usize,
}

impl LayerKv {
    fn new(d: usize) -> Self {
        Self { k: Vec::new(), v: Vec::new(), d, rows: 0, cap_rows: 0 }
    }

    /// Positions cached in this layer.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Positions the current allocation can hold before the next growth.
    pub fn capacity_rows(&self) -> usize {
        self.cap_rows
    }

    /// Cached key row for position `r`.
    pub fn k_row(&self, r: usize) -> &[f32] {
        &self.k[r * self.d..(r + 1) * self.d]
    }

    /// Cached value row for position `r`.
    pub fn v_row(&self, r: usize) -> &[f32] {
        &self.v[r * self.d..(r + 1) * self.d]
    }

    /// Geometric growth: double from [`INITIAL_CAP_ROWS`] until
    /// `want_rows` fits. Never shrinks.
    fn ensure(&mut self, want_rows: usize) {
        if want_rows <= self.cap_rows {
            return;
        }
        let mut cap = self.cap_rows.max(INITIAL_CAP_ROWS);
        while cap < want_rows {
            cap *= 2;
        }
        self.k.reserve_exact(cap * self.d - self.k.len());
        self.v.reserve_exact(cap * self.d - self.v.len());
        self.cap_rows = cap;
    }

    fn append(&mut self, k_rows: &Matrix, v_rows: &Matrix) {
        self.ensure(self.rows + k_rows.rows);
        self.k.extend_from_slice(&k_rows.data);
        self.v.extend_from_slice(&v_rows.data);
        self.rows += k_rows.rows;
    }

    /// Drop every cached position but keep the allocation (slides
    /// re-prefill into the same capacity).
    fn clear(&mut self) {
        self.k.clear();
        self.v.clear();
        self.rows = 0;
    }
}

/// Per-request KV cache: one [`LayerKv`] per transformer layer plus a
/// committed-position counter. See the module docs for the memory model.
#[derive(Debug, Clone)]
pub struct KvCache {
    layers: Vec<LayerKv>,
    d: usize,
    len: usize,
}

impl KvCache {
    /// Empty cache for a model with `n_layers` layers of width `d_model`.
    /// No memory is reserved until the first append.
    pub fn new(n_layers: usize, d_model: usize) -> Self {
        Self {
            layers: (0..n_layers).map(|_| LayerKv::new(d_model)).collect(),
            d: d_model,
            len: 0,
        }
    }

    /// Number of transformer layers this cache covers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Model width (columns of every cached row).
    pub fn d_model(&self) -> usize {
        self.d
    }

    /// Positions fully cached across every layer (committed by
    /// [`KvCache::commit`] at the end of a successful step).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no position is cached.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when every layer holds exactly the committed position count.
    /// An errored-out incremental step can leave a partial append; such a
    /// cache must be [`KvCache::clear`]ed (re-prefilled), never resumed.
    pub fn is_consistent(&self) -> bool {
        self.layers.iter().all(|l| l.rows() == self.len)
    }

    /// Row capacity of the first layer (all layers grow in lockstep, so
    /// this is the per-layer capacity the growth tests observe).
    pub fn capacity_rows(&self) -> usize {
        self.layers.first().map_or(0, |l| l.capacity_rows())
    }

    /// Heap bytes currently reserved across all layers (K + V, f32).
    pub fn reserved_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| 2 * l.capacity_rows() * self.d * std::mem::size_of::<f32>())
            .sum()
    }

    /// Read access to one layer's cached rows.
    pub fn layer(&self, l: usize) -> &LayerKv {
        &self.layers[l]
    }

    /// Append freshly projected K/V rows to `layer`. The interpreter
    /// calls this once per layer per step, then [`KvCache::commit`]s.
    pub fn append(&mut self, layer: usize, k_rows: &Matrix, v_rows: &Matrix) -> Result<()> {
        anyhow::ensure!(
            layer < self.layers.len(),
            "KV append to layer {layer} of a {}-layer cache",
            self.layers.len()
        );
        anyhow::ensure!(
            k_rows.cols == self.d && v_rows.cols == self.d,
            "KV rows of width {}/{} appended to a d_model={} cache",
            k_rows.cols,
            v_rows.cols,
            self.d
        );
        anyhow::ensure!(
            k_rows.rows == v_rows.rows,
            "K/V row-count mismatch: {} vs {}",
            k_rows.rows,
            v_rows.rows
        );
        // `kvcache.grow` failpoint: models an allocation failure, so it
        // only arms when this append would actually grow the layer. An
        // injected error propagates as a step error (partial append ⇒ the
        // caller must clear + re-prefill, per `is_consistent`).
        if self.layers[layer].rows() + k_rows.rows > self.layers[layer].capacity_rows() {
            crate::util::failpoint::check(crate::util::failpoint::sites::KVCACHE_GROW)?;
        }
        self.layers[layer].append(k_rows, v_rows);
        Ok(())
    }

    /// Mark `n` new positions fully cached, verifying every layer
    /// actually received them (a failed step that appended to only some
    /// layers is detected here and at the next step's consistency check).
    pub fn commit(&mut self, n: usize) -> Result<()> {
        let want = self.len + n;
        anyhow::ensure!(
            self.layers.iter().all(|l| l.rows() == want),
            "partial KV append: committing {want} positions but layer rows are {:?}",
            self.layers.iter().map(|l| l.rows()).collect::<Vec<_>>()
        );
        self.len = want;
        Ok(())
    }

    /// Invalidate every cached position, keeping the allocation. Used on
    /// window slides and after failed steps.
    pub fn clear(&mut self) {
        for l in &mut self.layers {
            l.clear();
        }
        self.len = 0;
    }
}

/// Decode progress for one in-flight request: the sliding context
/// window, the tokens generated so far, and (when the executor supports
/// incremental decode) the request's [`KvCache`].
///
/// The coordinator's continuous-batching loop owns a *set* of these,
/// admitting new states mid-flight and retiring finished ones; an
/// executor's `step` advances each active state by exactly one token.
#[derive(Debug, Clone)]
pub struct DecodeState {
    window: Vec<i32>,
    generated: Vec<i32>,
    max_new: usize,
    seq_cap: usize,
    cache: Option<KvCache>,
}

impl DecodeState {
    /// Oracle-path state (no cache): every step recomputes the whole
    /// window. `seq_cap` is the model context window; the window keeps
    /// the `seq_cap` newest prefix tokens.
    pub fn new(prefix: &[i32], max_new: usize, seq_cap: usize) -> Self {
        let cap = seq_cap.max(1);
        Self {
            window: prefix[prefix.len().saturating_sub(cap)..].to_vec(),
            generated: Vec::new(),
            max_new,
            seq_cap: cap,
            cache: None,
        }
    }

    /// Cached state: steps evaluate only the uncached window suffix.
    pub fn with_cache(prefix: &[i32], max_new: usize, seq_cap: usize, cache: KvCache) -> Self {
        let mut s = Self::new(prefix, max_new, seq_cap);
        s.cache = Some(cache);
        s
    }

    /// The current context window (the `seq_cap` newest tokens).
    pub fn window(&self) -> &[i32] {
        &self.window
    }

    /// Tokens generated so far, in order.
    pub fn generated(&self) -> &[i32] {
        &self.generated
    }

    /// This request's decode budget.
    pub fn max_new(&self) -> usize {
        self.max_new
    }

    /// True once `max_new` tokens have been generated (the request
    /// retires from the live set).
    pub fn done(&self) -> bool {
        self.generated.len() >= self.max_new
    }

    /// Whether this state carries a KV cache.
    pub fn has_cache(&self) -> bool {
        self.cache.is_some()
    }

    /// Window positions already covered by the cache (0 without one, or
    /// right after a slide cleared it).
    pub fn cached_rows(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.len())
    }

    /// Mutable cache access for the executor's decode step.
    pub fn cache_mut(&mut self) -> Option<&mut KvCache> {
        self.cache.as_mut()
    }

    /// The window suffix the next cached step must evaluate (tokens not
    /// yet covered by the cache) plus the cached-position count — the
    /// shared slicing contract of every cached executor step. Errors when
    /// the cache claims more positions than the window holds (a stale
    /// cache that somehow missed a slide invalidation).
    pub fn uncached_suffix(&self) -> Result<(Vec<i32>, usize)> {
        let cached = self.cached_rows();
        anyhow::ensure!(
            cached <= self.window.len(),
            "KV cache covers {cached} positions but the window has {}",
            self.window.len()
        );
        Ok((self.window[cached..].to_vec(), cached))
    }

    /// Record one generated token: appends to the window, sliding
    /// (drop-front) at the context cap. A slide shifts every absolute
    /// position — positional embeddings make all cached rows stale — so
    /// it clears the KV cache; the next step re-prefills the shifted
    /// window, which is exactly the recompute the oracle path performs
    /// at the cap.
    pub fn push_token(&mut self, tok: i32) {
        self.generated.push(tok);
        if self.window.len() >= self.seq_cap {
            self.window.remove(0);
            if let Some(c) = &mut self.cache {
                c.clear();
            }
        }
        self.window.push(tok);
    }

    /// Consume the state, yielding the generated tokens.
    pub fn into_generated(self) -> Vec<i32> {
        self.generated
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(n: usize, d: usize, base: f32) -> Matrix {
        Matrix::from_fn(n, d, |r, c| base + (r * d + c) as f32)
    }

    #[test]
    fn append_commit_and_row_access() {
        let mut c = KvCache::new(2, 4);
        assert_eq!(c.len(), 0);
        assert!(c.is_empty() && c.is_consistent());
        for l in 0..2 {
            c.append(l, &rows(3, 4, l as f32 * 100.0), &rows(3, 4, 500.0)).unwrap();
        }
        assert!(!c.is_consistent(), "uncommitted rows must read as inconsistent");
        c.commit(3).unwrap();
        assert_eq!(c.len(), 3);
        assert!(c.is_consistent());
        assert_eq!(c.layer(1).k_row(2), &[108.0, 109.0, 110.0, 111.0]);
        assert_eq!(c.layer(0).v_row(0), &[500.0, 501.0, 502.0, 503.0]);
    }

    #[test]
    fn capacity_grows_geometrically_and_survives_clear() {
        let mut c = KvCache::new(1, 2);
        assert_eq!(c.capacity_rows(), 0);
        c.append(0, &rows(1, 2, 0.0), &rows(1, 2, 0.0)).unwrap();
        c.commit(1).unwrap();
        assert_eq!(c.capacity_rows(), INITIAL_CAP_ROWS);
        // Cross the first growth boundary: 16 -> 32.
        c.append(0, &rows(INITIAL_CAP_ROWS, 2, 1.0), &rows(INITIAL_CAP_ROWS, 2, 1.0)).unwrap();
        c.commit(INITIAL_CAP_ROWS).unwrap();
        assert_eq!(c.capacity_rows(), 2 * INITIAL_CAP_ROWS);
        assert_eq!(c.len(), INITIAL_CAP_ROWS + 1);
        // Values survive growth: row 0 is still the first append.
        assert_eq!(c.layer(0).k_row(0), &[0.0, 1.0]);
        let reserved = c.reserved_bytes();
        assert_eq!(reserved, 2 * 2 * INITIAL_CAP_ROWS * 2 * 4);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.capacity_rows(), 2 * INITIAL_CAP_ROWS, "clear must keep capacity");
        assert_eq!(c.reserved_bytes(), reserved);
    }

    #[test]
    fn append_rejects_bad_shapes_and_commit_detects_partial() {
        let mut c = KvCache::new(2, 4);
        assert!(c.append(2, &rows(1, 4, 0.0), &rows(1, 4, 0.0)).is_err());
        assert!(c.append(0, &rows(1, 3, 0.0), &rows(1, 3, 0.0)).is_err());
        assert!(c.append(0, &rows(2, 4, 0.0), &rows(1, 4, 0.0)).is_err());
        // Append to layer 0 only: commit must refuse.
        c.append(0, &rows(1, 4, 0.0), &rows(1, 4, 0.0)).unwrap();
        assert!(c.commit(1).is_err());
        assert!(!c.is_consistent());
        c.clear();
        assert!(c.is_consistent());
    }

    #[test]
    fn decode_state_window_and_slide_semantics() {
        // Mirrors the serving decode contract: keep the newest `cap`
        // prefix tokens, slide at the cap, clear the cache on slide.
        let mut s = DecodeState::with_cache(&[1, 2, 3, 4, 5], 3, 4, KvCache::new(1, 2));
        assert_eq!(s.window(), &[2, 3, 4, 5]);
        assert!(!s.done());
        assert_eq!(s.cached_rows(), 0);
        // Simulate a prefill having cached the whole window.
        {
            let c = s.cache_mut().unwrap();
            c.append(0, &rows(4, 2, 0.0), &rows(4, 2, 0.0)).unwrap();
            c.commit(4).unwrap();
        }
        assert_eq!(s.cached_rows(), 4);
        s.push_token(9); // at cap: slides and invalidates
        assert_eq!(s.window(), &[3, 4, 5, 9]);
        assert_eq!(s.generated(), &[9]);
        assert_eq!(s.cached_rows(), 0, "slide must clear the cache");
        s.push_token(8);
        s.push_token(7);
        assert!(s.done());
        assert_eq!(s.into_generated(), vec![9, 8, 7]);
    }

    #[test]
    fn decode_state_short_prefix_grows_before_sliding() {
        let mut s = DecodeState::new(&[1], 4, 4);
        assert!(!s.has_cache());
        s.push_token(2);
        s.push_token(3);
        s.push_token(4);
        assert_eq!(s.window(), &[1, 2, 3, 4]);
        s.push_token(5); // first slide only once the window is full
        assert_eq!(s.window(), &[2, 3, 4, 5]);
        assert!(s.done());
    }

    #[test]
    fn empty_prefix_and_zero_budget() {
        let s = DecodeState::new(&[], 0, 8);
        assert!(s.window().is_empty());
        assert!(s.done(), "max_new = 0 is done before any step");
    }
}
