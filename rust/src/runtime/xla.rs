//! `PjrtBackend`: the real PJRT/XLA runtime, behind the `xla` cargo feature.
//!
//! Mirrors /opt/xla-example/load_hlo: HLO *text* is the interchange format
//! (jax ≥ 0.5 serialized protos are rejected by xla_extension 0.5.1; the
//! text parser reassigns instruction ids). Every lowered graph returns a
//! tuple (`return_tuple=True`), so outputs decompose with `to_tuple()`.
//!
//! The in-tree `third_party/xla` crate is an API stub whose client
//! constructor fails with a clear message; vendor the real `xla` crate at
//! that path (see README) to execute through actual PJRT.

use std::path::Path;

use anyhow::{Context, Result};

use super::backend::{Backend, Buffer, ExecutableImpl, Literal, LiteralData};

/// The PJRT/XLA runtime backend (`--features xla`).
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    /// Create a CPU PJRT client (errors on the in-tree API stub).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }
}

fn to_xla(lit: &Literal) -> Result<xla::Literal> {
    let dims_i64: Vec<i64> = lit.dims().iter().map(|&d| d as i64).collect();
    Ok(match &lit.data {
        LiteralData::F32(v) => xla::Literal::vec1(v).reshape(&dims_i64)?,
        LiteralData::I32(v) => xla::Literal::vec1(v).reshape(&dims_i64)?,
        LiteralData::I8(v) => {
            // SAFETY: reinterpreting `&[i8]` as `&[u8]` — identical size,
            // alignment and layout, same element count, read-only borrow
            // whose lifetime is bounded by `v` (used before `v` drops);
            // every bit pattern is valid for both types.
            #[allow(unsafe_code)] // crate denies unsafe; this audited cast is the one exception
            let bytes: &[u8] =
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len()) };
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S8,
                lit.dims(),
                bytes,
            )?
        }
    })
}

/// Outputs are consumed value-wise by the callers (scalars, flat logits),
/// so the converted literal keeps a flat shape.
fn from_xla(lit: &xla::Literal) -> Result<Literal> {
    let v: Vec<f32> = lit.to_vec()?;
    let n = v.len();
    Literal::f32(&v, &[n])
}

impl Backend for PjrtBackend {
    fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Upload once; reuse across many executions. This keeps large
    /// parameter sets resident (§Perf L3: the literal-input `execute` path
    /// re-transfers — and, in xla_extension 0.5.1, leaks — every argument
    /// on every call).
    fn upload(&self, lit: &Literal) -> Result<Buffer> {
        let xl = to_xla(lit)?;
        // A null device segfaults the CPU plugin — always pin device 0.
        let devices = self.client.addressable_devices();
        let dev = devices.first().context("no addressable device")?;
        let buf = self.client.buffer_from_host_literal(Some(dev), &xl)?;
        // BufferFromHostLiteral is asynchronous and the C wrapper does not
        // await the transfer; round-tripping the buffer forces readiness
        // while the host literal is still alive.
        let _ = buf.to_literal_sync()?;
        Ok(Buffer::Pjrt(buf))
    }

    fn load(&self, path: &Path) -> Result<Box<dyn ExecutableImpl>> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Box::new(PjrtExecutable { exe }))
    }
}

struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
}

impl ExecutableImpl for PjrtExecutable {
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let xinputs: Vec<xla::Literal> = inputs.iter().map(|l| to_xla(l)).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&xinputs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.iter().map(from_xla).collect()
    }

    fn run_buffers(&self, inputs: &[&Buffer]) -> Result<Vec<Literal>> {
        let bufs: Vec<&xla::PjRtBuffer> =
            inputs.iter().map(|b| b.as_pjrt()).collect::<Result<_>>()?;
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(&bufs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.iter().map(from_xla).collect()
    }
}
