//! The pluggable execution backend surface: host [`Literal`]s, device
//! [`Buffer`]s, and the [`Backend`]/[`ExecutableImpl`] traits every runtime
//! implements.
//!
//! Two backends exist:
//!
//! - [`super::sim::SimBackend`] (always available, the default): a pure-Rust
//!   dense-f32 interpreter of the stored AOT artifacts. No native deps, so
//!   the offline build is always green.
//! - `super::xla::PjrtBackend` (behind the `xla` cargo feature): the real
//!   PJRT path that parses and compiles the lowered HLO text. The in-tree
//!   `third_party/xla` crate is an API stub; vendor the real bindings to
//!   make it execute.

use std::path::Path;

use anyhow::{bail, Result};

use super::kvcache::KvCache;

/// A host tensor: typed flat data plus row-major dims. Scalars use `dims:
/// vec![]` (numel 1, like an XLA rank-0 literal).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    /// Row-major dimensions (empty for a rank-0 scalar).
    pub dims: Vec<usize>,
    /// The typed flat payload.
    pub data: LiteralData,
}

/// Typed flat storage behind a [`Literal`].
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    /// 32-bit floats (parameters, activations, logits).
    F32(Vec<f32>),
    /// 32-bit ints (token batches, sparse positions).
    I32(Vec<i32>),
    /// 8-bit ints (codebook indices).
    I8(Vec<i8>),
}

impl Literal {
    /// f32 literal of the given shape (length must match the shape).
    pub fn f32(data: &[f32], dims: &[usize]) -> Result<Self> {
        Self::check(data.len(), dims)?;
        Ok(Self { dims: dims.to_vec(), data: LiteralData::F32(data.to_vec()) })
    }

    /// i32 literal of the given shape (length must match the shape).
    pub fn i32(data: &[i32], dims: &[usize]) -> Result<Self> {
        Self::check(data.len(), dims)?;
        Ok(Self { dims: dims.to_vec(), data: LiteralData::I32(data.to_vec()) })
    }

    /// i8 literal of the given shape (length must match the shape).
    pub fn i8(data: &[i8], dims: &[usize]) -> Result<Self> {
        Self::check(data.len(), dims)?;
        Ok(Self { dims: dims.to_vec(), data: LiteralData::I8(data.to_vec()) })
    }

    /// Rank-0 f32 literal (the NLL graph outputs).
    pub fn scalar_f32(x: f32) -> Self {
        Self { dims: Vec::new(), data: LiteralData::F32(vec![x]) }
    }

    fn check(len: usize, dims: &[usize]) -> Result<()> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == len, "shape {:?} vs len {}", dims, len);
        Ok(())
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::I8(v) => v.len(),
        }
    }

    /// Row-major dimensions.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Borrow the payload as f32 (errors on other element types).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            LiteralData::F32(v) => Ok(v),
            other => bail!("literal is not f32: {other:?}"),
        }
    }

    /// Borrow the payload as i32 (errors on other element types).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            LiteralData::I32(v) => Ok(v),
            other => bail!("literal is not i32: {other:?}"),
        }
    }

    /// Borrow the payload as i8 (errors on other element types).
    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            LiteralData::I8(v) => Ok(v),
            other => bail!("literal is not i8: {other:?}"),
        }
    }

    /// Copy out as a typed vector (type inferred at the call site).
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::from_literal(self)
    }

    /// First element of the payload (the scalar-output graphs).
    pub fn get_first_element<T: Element>(&self) -> Result<T> {
        let v = self.to_vec::<T>()?;
        v.first().copied().ok_or_else(|| anyhow::anyhow!("empty literal"))
    }

    /// Argmax index over the f32 span `[base, base + width)` of the flat
    /// data — how the serving decode loop reads one vocab row out of a
    /// (B, S, V) logits literal without copying it out. NaNs lose ties.
    pub fn argmax_span(&self, base: usize, width: usize) -> Result<i32> {
        anyhow::ensure!(width > 0, "argmax over an empty span");
        let data = self.as_f32()?;
        anyhow::ensure!(
            base + width <= data.len(),
            "span {base}..{} outside literal of {} elements",
            base + width,
            data.len()
        );
        Ok(argmax_slice(&data[base..base + width]) as i32)
    }
}

/// Index of the largest value in `row` (first wins ties; NaNs lose) — the
/// single argmax every decode path shares, so packed and dense serving can
/// never diverge on tie-breaking. Returns 0 for an empty slice.
pub fn argmax_slice(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// Element types a [`Literal`] can hold.
pub trait Element: Copy + Sized {
    /// Copy the literal's payload out as this element type.
    fn from_literal(lit: &Literal) -> Result<Vec<Self>>;
}

impl Element for f32 {
    fn from_literal(lit: &Literal) -> Result<Vec<f32>> {
        Ok(lit.as_f32()?.to_vec())
    }
}

impl Element for i32 {
    fn from_literal(lit: &Literal) -> Result<Vec<i32>> {
        Ok(lit.as_i32()?.to_vec())
    }
}

impl Element for i8 {
    fn from_literal(lit: &Literal) -> Result<Vec<i8>> {
        Ok(lit.as_i8()?.to_vec())
    }
}

/// A backend-owned device buffer. Parameters are uploaded once and stay
/// resident across executions (§Perf L3); the sim backend's "device" is the
/// host, so its buffers simply own the literal.
pub enum Buffer {
    /// The sim backend's "device" buffer: the host literal itself.
    Host(Literal),
    /// A resident PJRT device buffer (`--features xla`).
    #[cfg(feature = "xla")]
    Pjrt(xla::PjRtBuffer),
}

impl Buffer {
    /// Borrow as a host literal (errors on PJRT buffers).
    pub fn as_host(&self) -> Result<&Literal> {
        match self {
            Buffer::Host(l) => Ok(l),
            #[cfg(feature = "xla")]
            Buffer::Pjrt(_) => bail!("buffer belongs to the PJRT backend, not the sim backend"),
        }
    }

    /// Borrow as a PJRT device buffer (errors on host literals).
    #[cfg(feature = "xla")]
    pub fn as_pjrt(&self) -> Result<&xla::PjRtBuffer> {
        match self {
            Buffer::Pjrt(b) => Ok(b),
            Buffer::Host(_) => bail!("buffer belongs to the sim backend, not the PJRT backend"),
        }
    }
}

/// What every runtime backend provides. Deliberately NOT `Send`: real PJRT
/// handles must stay on the thread that created them (the coordinator
/// constructs its executor inside the executor thread for this reason).
pub trait Backend {
    /// Human-readable platform name (e.g. `sim-cpu`).
    fn platform_name(&self) -> String;
    /// Upload a host literal into a resident device buffer.
    fn upload(&self, lit: &Literal) -> Result<Buffer>;
    /// Load (and for PJRT, compile) a graph artifact.
    fn load(&self, path: &Path) -> Result<Box<dyn ExecutableImpl>>;
    /// True when loaded model graphs accept any leading batch dimension
    /// (the sim interpreter reads B from the token literal). PJRT compiles
    /// a static (B, S), so its executables must be fed full-size batches.
    fn supports_dynamic_batch(&self) -> bool {
        false
    }
    /// True when this backend's forward graphs can decode incrementally
    /// against a per-request [`KvCache`] (see
    /// [`ExecutableImpl::run_decode_step`]). The sim interpreter supports
    /// it; PJRT compiles a fixed-shape graph with no cache inputs, so its
    /// decode loop recomputes the full prefix every step.
    fn supports_incremental_decode(&self) -> bool {
        false
    }
}

/// A loaded computation ready for repeated execution.
pub trait ExecutableImpl {
    /// Execute with positional host literals; returns the flattened output
    /// tuple elements.
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>>;
    /// Execute with pre-uploaded device buffers (the hot path).
    fn run_buffers(&self, inputs: &[&Buffer]) -> Result<Vec<Literal>>;
    /// True when this loaded graph supports [`run_decode_step`]
    /// (only the sim backend's `fwd` model graphs do).
    ///
    /// [`run_decode_step`]: ExecutableImpl::run_decode_step
    fn supports_incremental_decode(&self) -> bool {
        false
    }
    /// KV-cached incremental decode step: evaluate only `tokens` (the
    /// window suffix at absolute positions `pos0..pos0 + tokens.len()`),
    /// attending against — and appending to — the per-request `cache`.
    /// `params` are the resident parameter buffers in canonical order
    /// (no token literal). Returns the `(tokens.len(), vocab)` logits
    /// for the new positions, bit-identical to the rows a full-prefix
    /// [`run`](ExecutableImpl::run) would produce (pinned by
    /// `tests/decode_equiv.rs`).
    fn run_decode_step(
        &self,
        params: &[&Buffer],
        tokens: &[i32],
        pos0: usize,
        cache: &mut KvCache,
    ) -> Result<Literal> {
        let _ = (params, tokens, pos0, cache);
        bail!("this graph does not support incremental decode")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_checked() {
        assert!(Literal::f32(&[1.0, 2.0], &[2, 2]).is_err());
        let l = Literal::f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.numel(), 4);
        assert_eq!(l.dims(), &[2, 2]);
    }

    #[test]
    fn scalar_literal() {
        let s = Literal::scalar_f32(2.5);
        assert_eq!(s.numel(), 1);
        assert!(s.dims().is_empty());
        assert_eq!(s.get_first_element::<f32>().unwrap(), 2.5);
    }

    #[test]
    fn argmax_span_reads_one_row() {
        // Two "vocab rows" of width 4 packed flat.
        let l = Literal::f32(&[0.1, 0.9, 0.2, 0.3, 5.0, -1.0, 4.0, 4.5], &[2, 4]).unwrap();
        assert_eq!(l.argmax_span(0, 4).unwrap(), 1);
        assert_eq!(l.argmax_span(4, 4).unwrap(), 0);
        assert!(l.argmax_span(6, 4).is_err()); // out of range
        assert!(l.argmax_span(0, 0).is_err()); // empty span
        let i = Literal::i32(&[1, 2], &[2]).unwrap();
        assert!(i.argmax_span(0, 2).is_err()); // not f32
    }

    #[test]
    fn typed_extraction() {
        let l = Literal::i32(&[7, 8], &[2]).unwrap();
        let v: Vec<i32> = l.to_vec().unwrap();
        assert_eq!(v, vec![7, 8]);
        assert!(l.to_vec::<f32>().is_err());
        let b = Buffer::Host(l);
        assert_eq!(b.as_host().unwrap().numel(), 2);
    }
}
