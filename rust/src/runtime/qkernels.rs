//! Native quantized execution engine: matmul directly on packed HALO
//! codebook tiles, with the hypersparse outlier matrix fused as an SpMV
//! epilogue and a per-tile DVFS cycle-cost model.
//!
//! This is the serving-side counterpart of the paper's premise that the
//! quantized form *is* the execution format. The dense path dequantizes
//! every layer back to f32 before the graph runs; here the forward pass
//! consumes [`PackedLayer`]s as-is:
//!
//! - [`qmatmul`] walks the layer one tile-column panel at a time. Each
//!   tile's `u8` codes are expanded through its 16-entry LUT
//!   (`table[code] * scale`) into an L1-resident panel, which a 4-row
//!   register-blocked micro-kernel (the blocking scheme of
//!   [`super::kernels`]) accumulates against the activations. Panels are
//!   fanned out over the worker pool; each task owns disjoint output
//!   columns and walks `k` in ascending order, so results are
//!   deterministic and thread-count independent.
//! - The `< 0.5 %` outlier/salient side matrix lands via
//!   [`crate::quant::sparse::SparseMatrix::spmv_into`] **after** the dense
//!   accumulation — a fused epilogue, not a scatter into a dense copy.
//! - [`QCost`] prices every tile at its DVFS class frequency
//!   ([`crate::mac::MacProfile`] classes mapped onto a
//!   [`crate::dvfs::Ladder`]), giving the modeled speedup/energy that the
//!   serving CLI reports alongside wall-clock throughput.
//!
//! [`PackedModel`] is the parameter store for this path: packed tiles for
//! every linear weight, dense data only for the non-linear parameters
//! (embeddings, norms, biases). It never materializes a dense f32 linear
//! weight — [`PackedModel::dense_linear_count`] exists so tests can assert
//! exactly that.

#![warn(missing_docs)]

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::dvfs::{FreqClass, Ladder, Schedule};
use crate::mac::MacProfile;
use crate::quant::packed::PackedLayer;
use crate::quant::{HaloConfig, HaloQuantizer, LayerCtx, Matrix, Variant};
use crate::util::parallel;

use super::artifacts::ModelArtifacts;
use super::kvcache::{DecodeState, KvCache};
use super::sim::{self, ModelSpec, ParamSource};

/// Output rows accumulated together per micro-kernel pass (register
/// blocking factor, mirroring `runtime::kernels::MR`).
const MR: usize = 4;

/// Below this many MACs the panel fan-out costs more than it saves; run
/// the tile columns serially (mirrors `kernels::PAR_MIN_MACS`).
const PAR_MIN_MACS: usize = 1 << 17;

/// `y = x @ W` executed natively on a packed layer, outliers fused as an
/// SpMV epilogue. `x` is `(m, K)` row-major; the result is `(m, N)`.
///
/// Bit-for-bit deterministic: per output element, `k` ascends tile-row by
/// tile-row exactly like the dense blocked kernel, and the parallel panel
/// tasks own disjoint columns.
pub fn qmatmul(x: &Matrix, layer: &PackedLayer) -> Matrix {
    assert_eq!(
        x.cols,
        layer.rows(),
        "qmatmul: inner dims {} vs {} ({})",
        x.cols,
        layer.rows(),
        layer.name
    );
    let (m, n) = (x.rows, layer.cols());
    let grid = layer.grid;
    let mut out = Matrix::zeros(m, n);
    if m == 0 || n == 0 || layer.rows() == 0 {
        return out;
    }

    let panel_task = |tc: usize| -> Vec<f32> {
        let c0 = tc * grid.tile;
        let nw = (c0 + grid.tile).min(n) - c0;
        let mut y = vec![0.0f32; m * nw];
        let mut wbuf = vec![0.0f32; grid.tile * nw];
        for tr in 0..grid.tiles_r {
            let tile = &layer.tiles[tr * grid.tiles_c + tc];
            debug_assert_eq!(tile.cols, nw);
            let (k0, kh) = (tr * grid.tile, tile.rows);
            // LUT expansion: 16 multiplies, then one table read per code.
            let mut lut = [0.0f32; crate::quant::packed::TABLE_LEN];
            for (slot, &v) in lut.iter_mut().zip(layer.table.iter()) {
                *slot = v * tile.scale;
            }
            for (wv, &code) in wbuf[..kh * nw].iter_mut().zip(tile.codes.iter()) {
                *wv = lut[code as usize];
            }
            accumulate_panel(x, k0, kh, &wbuf[..kh * nw], nw, &mut y, m);
        }
        y
    };

    let work = m * layer.rows() * n;
    let panels: Vec<Vec<f32>> = if work < PAR_MIN_MACS {
        (0..grid.tiles_c).map(panel_task).collect()
    } else {
        parallel::par_map(grid.tiles_c, panel_task)
    };
    for (tc, panel) in panels.into_iter().enumerate() {
        let c0 = tc * grid.tile;
        let nw = (c0 + grid.tile).min(n) - c0;
        for r in 0..m {
            out.row_mut(r)[c0..c0 + nw].copy_from_slice(&panel[r * nw..(r + 1) * nw]);
        }
    }

    // Fused epilogue: the hypersparse side matrix adds straight into the
    // output — the dense weight plane is never reconstructed.
    layer.sparse.spmv_into(x, &mut out);
    out
}

/// Accumulate `y[(m, nw)] += x[:, k0..k0+kh] @ w[(kh, nw)]` with 4-row
/// register blocking: each streamed `w` row is reused `MR`× from
/// registers, and `k` ascends so the summation order matches the dense
/// oracle.
fn accumulate_panel(
    x: &Matrix,
    k0: usize,
    kh: usize,
    w: &[f32],
    nw: usize,
    y: &mut [f32],
    m: usize,
) {
    let xk = x.cols;
    let xd = &x.data;
    let mut r = 0usize;
    while r + MR <= m {
        let (r01, r23) = y[r * nw..(r + MR) * nw].split_at_mut(2 * nw);
        let (o0, o1) = r01.split_at_mut(nw);
        let (o2, o3) = r23.split_at_mut(nw);
        for kk in 0..kh {
            let a0 = xd[r * xk + k0 + kk];
            let a1 = xd[(r + 1) * xk + k0 + kk];
            let a2 = xd[(r + 2) * xk + k0 + kk];
            let a3 = xd[(r + 3) * xk + k0 + kk];
            let wrow = &w[kk * nw..(kk + 1) * nw];
            for (j, &wv) in wrow.iter().enumerate() {
                o0[j] += a0 * wv;
                o1[j] += a1 * wv;
                o2[j] += a2 * wv;
                o3[j] += a3 * wv;
            }
        }
        r += MR;
    }
    while r < m {
        let orow = &mut y[r * nw..(r + 1) * nw];
        for kk in 0..kh {
            let av = xd[r * xk + k0 + kk];
            if av == 0.0 {
                continue;
            }
            let wrow = &w[kk * nw..(kk + 1) * nw];
            for (j, &wv) in wrow.iter().enumerate() {
                orow[j] += av * wv;
            }
        }
        r += 1;
    }
}

// ---------------------------------------------------------------- cost model

/// Per-tile cycle-cost model over one or more packed layers: every tile is
/// priced at its DVFS class frequency, the SpMV side at the base level on
/// its own engine (concurrent, like the systolic simulator's dataflow).
/// All times are per activation row, single-MAC-lane normalized — the
/// absolute scale cancels in the speedup/energy ratios this model exists
/// to report.
#[derive(Debug, Clone, Copy, Default)]
pub struct QCost {
    /// Modeled dense-tile time per activation row (s), tiles at class clocks.
    pub modeled_s: f64,
    /// The same work priced entirely at the base clock (the uniform-quant
    /// reference point).
    pub base_s: f64,
    /// SpMV engine time per activation row (s), base clock.
    pub spmv_s: f64,
    /// Dynamic MAC energy per activation row (pJ), V²-scaled per class.
    pub energy_pj: f64,
    /// Bytes the packed representation touches per pass.
    pub packed_bytes: usize,
    /// Bytes a dense f32 copy would touch per pass.
    pub dense_bytes: usize,
    /// Tiles per DVFS class, indexed by `FreqClass as usize`.
    pub class_tiles: [usize; 3],
    /// Live sparse entries routed to the SpMV engine.
    pub sparse_nnz: usize,
}

impl QCost {
    /// Accumulate the cost of `layer` under `ladder` clocks.
    pub fn add_layer(&mut self, layer: &PackedLayer, ladder: &Ladder) {
        let v_nom = crate::mac::power::V_NOM;
        for tile in &layer.tiles {
            let level = ladder.level(tile.class);
            let macs = tile.macs() as f64;
            self.modeled_s += macs / (level.ghz * 1e9);
            self.energy_pj += macs * tile.energy_pj * (level.volts / v_nom).powi(2);
            self.class_tiles[tile.class as usize] += 1;
        }
        let base = ladder.level(FreqClass::Base);
        self.base_s += layer.macs_per_row() as f64 / (base.ghz * 1e9);
        self.spmv_s += layer.sparse.nnz as f64 / (base.ghz * 1e9);
        self.packed_bytes += layer.packed_bytes();
        self.dense_bytes += layer.dense_bytes();
        self.sparse_nnz += layer.sparse.nnz;
    }

    /// Modeled speedup of class-clocked packed execution over the same
    /// MACs at the base clock (SpMV engine runs concurrently, so the
    /// slower stream bounds the pass).
    pub fn modeled_speedup(&self) -> f64 {
        self.base_s / self.modeled_s.max(self.spmv_s).max(1e-30)
    }

    /// Weight-traffic reduction: dense f32 bytes over packed bytes.
    pub fn bytes_saving(&self) -> f64 {
        self.dense_bytes as f64 / self.packed_bytes.max(1) as f64
    }

    /// One-line human summary for the serving CLI.
    pub fn summary(&self) -> String {
        format!(
            "modeled speedup {:.2}x vs base clock, bytes {:.2}x smaller ({} fast / {} med / {} base tiles, {} sparse nnz)",
            self.modeled_speedup(),
            self.bytes_saving(),
            self.class_tiles[FreqClass::Fast as usize],
            self.class_tiles[FreqClass::Med as usize],
            self.class_tiles[FreqClass::Base as usize],
            self.sparse_nnz
        )
    }
}

// ------------------------------------------------------------- packed store

/// Parameter store for native quantized execution: every linear weight as
/// a [`PackedLayer`], dense data only for embeddings/norms/biases. The
/// whole-model DVFS [`Schedule`] (class-clustered over all layers' tiles)
/// rides along for the serving executors.
#[derive(Debug)]
pub struct PackedModel {
    /// Transformer hyper-parameters + canonical parameter table.
    pub spec: ModelSpec,
    /// Non-linear parameters by name: (shape, flat data).
    dense: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    /// Packed quantized linear layers by name.
    layers: BTreeMap<String, PackedLayer>,
    /// Class-clustered DVFS schedule over every layer's tiles, in
    /// canonical layer order.
    pub schedule: Schedule,
}

impl PackedModel {
    /// Quantize and pack every linear parameter of `spec`. `params` yields
    /// borrowed `(name, shape, data)` views in any order (names must match
    /// the spec) — only one layer's dense weights are materialized at a
    /// time, so packing never doubles the resident model. `grads` supplies
    /// Fisher gradients for saliency/sensitivity where available.
    pub fn pack_from<'a>(
        spec: ModelSpec,
        params: impl IntoIterator<Item = (&'a str, &'a [usize], &'a [f32])>,
        variant: Variant,
        tile: usize,
        grads: &BTreeMap<String, Matrix>,
        profile: &MacProfile,
    ) -> Result<Self> {
        let q = HaloQuantizer::new(HaloConfig::new(tile, variant), profile);
        let mut dense = BTreeMap::new();
        let mut layers = BTreeMap::new();
        let mut classes = Vec::new();
        for (name, shape, data) in params {
            let i = spec
                .names
                .iter()
                .position(|n| n == name)
                .with_context(|| format!("parameter {name} not in model spec"))?;
            // Fail at pack time, not deep inside a shard's forward pass.
            anyhow::ensure!(
                shape == spec.shapes[i].as_slice(),
                "parameter {name}: shape {shape:?} != spec {:?}",
                spec.shapes[i]
            );
            anyhow::ensure!(
                data.len() == shape.iter().product::<usize>(),
                "parameter {name}: data length {} != shape {shape:?}",
                data.len()
            );
            if spec.linear[i] {
                anyhow::ensure!(shape.len() == 2, "linear parameter {name} is not 2-D");
                let w = Matrix::from_vec(shape[0], shape[1], data.to_vec());
                let ctx = match grads.get(name) {
                    Some(g) => LayerCtx::with_grad(name, g),
                    None => LayerCtx::new(name),
                };
                let (res, pay) = q.quantize_full(&w, &ctx);
                let packed = PackedLayer::pack(name, &res, &pay, profile);
                classes.extend(packed.classes());
                let prev = layers.insert(name.to_string(), packed);
                anyhow::ensure!(prev.is_none(), "duplicate parameter {name}");
            } else {
                let prev = dense.insert(name.to_string(), (shape.to_vec(), data.to_vec()));
                anyhow::ensure!(prev.is_none(), "duplicate parameter {name}");
            }
        }
        for (i, name) in spec.names.iter().enumerate() {
            let present = if spec.linear[i] {
                layers.contains_key(name)
            } else {
                dense.contains_key(name)
            };
            anyhow::ensure!(present, "model parameter {name} missing from pack input");
        }
        let schedule = Schedule::cluster(&classes);
        Ok(Self { spec, dense, layers, schedule })
    }

    /// Pack a trained model from the artifact store (the `halo serve
    /// --quant` path). Reads the spec from the sibling `config.json`;
    /// parameter data is borrowed, never bulk-cloned.
    pub fn pack_artifacts(
        model: &ModelArtifacts,
        variant: Variant,
        tile: usize,
        grads: &BTreeMap<String, Matrix>,
        profile: &MacProfile,
    ) -> Result<Self> {
        let spec = ModelSpec::load(&model.dir)?;
        let params = model
            .params
            .iter()
            .map(|p| (p.name.as_str(), p.shape.as_slice(), p.data.as_slice()));
        Self::pack_from(spec, params, variant, tile, grads, profile)
    }

    /// Logits for a `(b, s)` token batch, executed natively on the packed
    /// layers (codebook kernels + fused SpMV). Returns a `(b·s, vocab)`
    /// matrix.
    pub fn forward(&self, tokens: &[i32], b: usize, s: usize) -> Result<Matrix> {
        let src = PackedParams(self);
        let (logits, _, _) = sim::forward(&self.spec, &src, tokens, b, s, false)?;
        Ok(logits)
    }

    /// KV-cached incremental forward step, natively on the packed layers:
    /// evaluates only `tokens` (the window suffix at absolute positions
    /// `pos0..`), attending against — and appending to — `cache`. Every
    /// linear GEMM still routes through [`qmatmul`] + fused SpMV, so the
    /// packed path gets incremental decode from the shared interpreter
    /// for free (see [`sim::forward_incremental`]). Bit-identical to
    /// [`PackedModel::forward`] over the whole window, pinned by
    /// `tests/decode_equiv.rs`.
    pub fn forward_incremental(
        &self,
        tokens: &[i32],
        pos0: usize,
        cache: &mut KvCache,
    ) -> Result<Matrix> {
        let src = PackedParams(self);
        sim::forward_incremental(&self.spec, &src, tokens, pos0, cache, false)
    }

    /// Fresh, empty KV cache shaped for this model.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.spec.n_layers, self.spec.d_model)
    }

    /// Greedy (argmax) single-sequence decode on the packed layers,
    /// KV-cached — `max_new` tokens, sliding the context window at
    /// `seq_len` exactly like the serving decode loop: the first step
    /// prefills the window, every later step evaluates only the newest
    /// token, and a slide re-bases the cache instead of clearing it
    /// (ring positions; see `runtime::kvcache`). Bit-identical to the
    /// serving `QuantExecutor` path and, on chains that never slide, to
    /// [`PackedModel::decode_greedy_recompute`] (pinned by
    /// `tests/decode_equiv.rs`). The client-side oracle
    /// `halo loadgen --quant` re-derives sampled response chains against
    /// this.
    pub fn decode_greedy(&self, prefix: &[i32], max_new: usize) -> Result<Vec<i32>> {
        let mut s = DecodeState::with_cache(prefix, max_new, self.spec.seq_len, self.new_cache());
        while !s.done() {
            let (new, cached) = s.uncached_suffix()?;
            let t = if new.is_empty() {
                // Empty window (empty prefix): pad one position, same as
                // the recompute path, without touching the cache.
                let logits = self.forward(&[0], 1, 1)?;
                super::backend::argmax_slice(logits.row(0)) as i32
            } else {
                let logits = match s.cache_mut() {
                    Some(cache) => self.forward_incremental(&new, cached, cache)?,
                    None => anyhow::bail!("decode state constructed with a cache lost it"),
                };
                super::backend::argmax_slice(logits.row(new.len() - 1)) as i32
            };
            s.push_token(t);
        }
        Ok(s.into_generated())
    }

    /// Cache-free oracle decode: every step re-runs the whole live
    /// window through [`PackedModel::forward`]. O(S²) — kept as the
    /// differential oracle for the cached path (`halo loadgen --quant
    /// --no-kv-cache` verifies against this) and for chains where an
    /// independent recomputation is wanted.
    pub fn decode_greedy_recompute(&self, prefix: &[i32], max_new: usize) -> Result<Vec<i32>> {
        let cap = self.spec.seq_len;
        let mut seq: Vec<i32> = prefix[prefix.len().saturating_sub(cap)..].to_vec();
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            let n = seq.len().min(cap).max(1);
            let mut tokens = vec![0i32; n];
            let live = seq.len().min(n);
            tokens[..live].copy_from_slice(&seq[seq.len() - live..]);
            let logits = self.forward(&tokens, 1, n)?;
            let t = super::backend::argmax_slice(logits.row(n - 1)) as i32;
            out.push(t);
            if seq.len() >= cap {
                seq.remove(0);
            }
            seq.push(t);
        }
        Ok(out)
    }

    /// The packed layer for a linear parameter, if packed.
    pub fn layer(&self, name: &str) -> Option<&PackedLayer> {
        self.layers.get(name)
    }

    /// Iterate over every packed layer in name order.
    pub fn packed_layers(&self) -> impl Iterator<Item = &PackedLayer> {
        self.layers.values()
    }

    /// Number of packed (linear) layers.
    pub fn n_packed(&self) -> usize {
        self.layers.len()
    }

    /// Dense flat data for a non-linear parameter, if stored dense.
    pub fn dense_param(&self, name: &str) -> Option<&[f32]> {
        self.dense.get(name).map(|(_, d)| d.as_slice())
    }

    /// How many *linear* parameters are held as dense f32 — always 0: the
    /// store keeps linear weights only in packed form. Tests assert this
    /// to pin the never-densify guarantee.
    pub fn dense_linear_count(&self) -> usize {
        self.spec
            .names
            .iter()
            .enumerate()
            .filter(|(i, name)| self.spec.linear[*i] && self.dense.contains_key(*name))
            .count()
    }

    /// Aggregate per-tile cycle-cost model under `ladder` clocks.
    pub fn cost(&self, ladder: &Ladder) -> QCost {
        let mut c = QCost::default();
        for layer in self.layers.values() {
            c.add_layer(layer, ladder);
        }
        c
    }

    /// Materialize this packed model as an owned dense
    /// [`sim::DenseParams`] store: every packed linear layer is
    /// dequantized ([`PackedLayer::dequantize`]), everything else copied
    /// from the dense map. This is the speculative *drafter* fast path
    /// (`coordinator::spec`): the expansion keeps the packed variant's
    /// numerics (within the LUT kernels' summation-order tolerance, see
    /// the `qmatmul_matches_dequantize_then_dense` pin) while decoding
    /// through the dense kernels — which matters because packed decode
    /// runs ~0.55x dense wall-clock (BENCH_PR4 `throughput_ratio`), so a
    /// natively packed drafter could never be cheaper than its verifier.
    /// One-time cost at executor construction; the model's own
    /// never-densify store is untouched
    /// ([`PackedModel::dense_linear_count`] stays 0).
    pub fn expand_params(&self) -> Result<sim::DenseParams> {
        let mut owned: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
        for (i, name) in self.spec.names.iter().enumerate() {
            if self.spec.linear[i] {
                let layer = self
                    .layers
                    .get(name)
                    .with_context(|| format!("packed layer {name} missing"))?;
                let w = layer.dequantize();
                owned.push((name.clone(), vec![w.rows, w.cols], w.data));
            } else {
                let (shape, data) = self
                    .dense
                    .get(name)
                    .with_context(|| format!("dense parameter {name} missing"))?;
                owned.push((name.clone(), shape.clone(), data.clone()));
            }
        }
        sim::DenseParams::from_params(
            &self.spec,
            owned.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice())),
        )
    }
}

/// [`ParamSource`] adapter: dense lookups from the non-linear map, linear
/// GEMMs through [`qmatmul`]. `mat()` on a packed layer is an error by
/// design — that is the densification this engine exists to avoid.
struct PackedParams<'a>(&'a PackedModel);

impl ParamSource for PackedParams<'_> {
    fn vec1(&self, name: &str) -> Result<&[f32]> {
        self.0
            .dense_param(name)
            .ok_or_else(|| anyhow::anyhow!("missing dense parameter {name}"))
    }

    fn mat(&self, name: &str) -> Result<Matrix> {
        if self.0.layers.contains_key(name) {
            anyhow::bail!("{name} is packed; the quantized path never densifies it");
        }
        let (shape, data) = self
            .0
            .dense
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing parameter {name}"))?;
        anyhow::ensure!(shape.len() == 2, "parameter {name} is not 2-D: {shape:?}");
        Ok(Matrix::from_vec(shape[0], shape[1], data.clone()))
    }

    fn linmul(&self, x: &Matrix, name: &str) -> Result<Matrix> {
        let layer = self
            .0
            .layers
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing packed layer {name}"))?;
        Ok(qmatmul(x, layer))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kernels;
    use crate::util::Rng;

    fn packed_layer(rows: usize, cols: usize, tile: usize, seed: u64) -> (Matrix, PackedLayer) {
        let profile = MacProfile::cached();
        let mut rng = Rng::seed_from_u64(seed);
        let w = Matrix::random_normal(rows, cols, 0.02, &mut rng);
        let g = Matrix::random_normal(rows, cols, 1.0, &mut rng);
        let q = HaloQuantizer::new(HaloConfig::new(tile, Variant::Bal), profile);
        let (res, pay) = q.quantize_full(&w, &LayerCtx::with_grad("t", &g));
        (w, PackedLayer::pack("t", &res, &pay, profile))
    }

    #[test]
    fn qmatmul_matches_dequantize_then_dense() {
        let mut rng = Rng::seed_from_u64(100);
        for (m, k, n, tile) in [(4, 32, 32, 16), (7, 96, 64, 32), (1, 64, 96, 32)] {
            let (_, layer) = packed_layer(k, n, tile, 200 + m as u64);
            let x = Matrix::random_normal(m, k, 1.0, &mut rng);
            let got = qmatmul(&x, &layer);
            let want = kernels::matmul(&x, &layer.dequantize());
            for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                    "({m},{k},{n},t{tile})[{i}]: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn qmatmul_thread_count_independent() {
        let _guard = crate::util::parallel::THREAD_CAP_TEST_LOCK.lock().unwrap();
        let (_, layer) = packed_layer(128, 128, 32, 77);
        let mut rng = Rng::seed_from_u64(78);
        let x = Matrix::random_normal(16, 128, 1.0, &mut rng);
        let par = qmatmul(&x, &layer);
        crate::util::parallel::set_max_threads(1);
        let ser = qmatmul(&x, &layer);
        crate::util::parallel::set_max_threads(0);
        assert_eq!(par.data, ser.data, "qmatmul must be deterministic");
    }

    #[test]
    fn pack_from_rejects_bad_shapes_and_duplicates() {
        let spec = ModelSpec::synthetic(11, 8, 1, 2, 16, 6);
        let profile = MacProfile::cached();
        let grads = BTreeMap::new();
        let base: Vec<(String, Vec<usize>, Vec<f32>)> = spec
            .names
            .iter()
            .zip(&spec.shapes)
            .map(|(n, sh)| (n.clone(), sh.clone(), vec![0.01f32; sh.iter().product()]))
            .collect();
        let pack = |p: &[(String, Vec<usize>, Vec<f32>)]| {
            let views = p.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
            PackedModel::pack_from(spec.clone(), views, Variant::Bal, 4, &grads, profile)
        };

        assert!(pack(&base).is_ok());

        // Mis-shaped pos_embed must fail at pack time, not at serve time.
        let mut bad = base.clone();
        bad[1].1 = vec![3, 8];
        bad[1].2 = vec![0.01f32; 24];
        assert!(pack(&bad).is_err());

        // Duplicate parameter names must be rejected, not silently merged.
        let mut dup = base.clone();
        let first = dup[2].clone();
        dup.push(first);
        assert!(pack(&dup).is_err());
    }

    /// Seeded tiny packed model for the incremental / expansion pins.
    fn tiny_packed(seed: u64, variant: Variant) -> (ModelSpec, PackedModel) {
        let spec = ModelSpec::synthetic(11, 8, 1, 2, 16, 6);
        let profile = MacProfile::cached();
        let mut rng = Rng::seed_from_u64(seed);
        let mut params: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
        let mut grads = BTreeMap::new();
        for (i, (name, shape)) in spec.names.iter().zip(&spec.shapes).enumerate() {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if name.ends_with(".scale") {
                vec![1.0; n]
            } else {
                (0..n).map(|_| rng.gen_normal() as f32 * 0.1).collect()
            };
            if spec.linear[i] {
                grads.insert(
                    name.clone(),
                    Matrix::from_fn(shape[0], shape[1], |_, _| rng.gen_normal() as f32),
                );
            }
            params.push((name.clone(), shape.clone(), data));
        }
        let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
        let pm = PackedModel::pack_from(spec.clone(), views, variant, 4, &grads, profile).unwrap();
        (spec, pm)
    }

    #[test]
    fn packed_incremental_matches_packed_full_bitexact() {
        // The packed path inherits incremental decode from the shared
        // interpreter: prefill + single-token steps must reproduce the
        // full-window logits rows exactly.
        let (spec, pm) = tiny_packed(321, Variant::Bal);
        let s = spec.seq_len;
        let toks: Vec<i32> = (0..s as i32).map(|t| (t * 5 + 2) % spec.vocab as i32).collect();
        let full = pm.forward(&toks, 1, s).unwrap();
        let mut cache = pm.new_cache();
        let pre = pm.forward_incremental(&toks[..2], 0, &mut cache).unwrap();
        assert_eq!(pre.row(0), full.row(0));
        assert_eq!(pre.row(1), full.row(1));
        for i in 2..s {
            let one = pm.forward_incremental(&toks[i..i + 1], i, &mut cache).unwrap();
            assert_eq!(one.row(0), full.row(i), "packed incremental step {i}");
        }
    }

    #[test]
    fn expand_params_tracks_packed_numerics() {
        // The drafter expansion must reproduce the packed chain's
        // numerics up to the LUT kernels' summation-order tolerance
        // (`qmatmul_matches_dequantize_then_dense`), without densifying
        // the packed store itself.
        let (spec, pm) = tiny_packed(654, Variant::PerfOpt);
        let dp = pm.expand_params().unwrap();
        assert_eq!(pm.dense_linear_count(), 0, "expansion must not densify the store");

        let s = spec.seq_len;
        let toks: Vec<i32> = (0..s as i32).map(|t| (t * 3 + 1) % spec.vocab as i32).collect();
        let packed = pm.forward(&toks, 1, s).unwrap();
        let (dense, _, _) = sim::forward(&spec, &dp, &toks, 1, s, false).unwrap();
        assert_eq!((packed.rows, packed.cols), (dense.rows, dense.cols));
        for (i, (a, b)) in packed.data.iter().zip(&dense.data).enumerate() {
            assert!(
                (a - b).abs() <= 5e-3 * (1.0 + b.abs()),
                "expanded logits diverge at [{i}]: packed {a} vs expanded {b}"
            );
        }
    }

    #[test]
    fn cost_model_speedup_and_bytes() {
        let (_, layer) = packed_layer(128, 128, 32, 5);
        let mut c = QCost::default();
        c.add_layer(&layer, &Ladder::paper_systolic());
        // Codebook-pure tiles clock above base: strict modeled speedup.
        assert!(c.modeled_speedup() > 1.0, "{}", c.modeled_speedup());
        assert!(c.modeled_speedup() <= 3.7 / 1.9 + 1e-9);
        assert!(c.bytes_saving() > 3.0, "{}", c.bytes_saving());
        let tiles: usize = c.class_tiles.iter().sum();
        assert_eq!(tiles, layer.tiles.len());
        assert!(c.energy_pj > 0.0);
    }
}
