//! Native quantized execution engine: integer W4A8 matmul directly on
//! packed HALO codebook tiles, with the hypersparse outlier matrix fused
//! as an SpMV epilogue and a per-tile DVFS cycle-cost model.
//!
//! This is the serving-side counterpart of the paper's premise that the
//! quantized form *is* the execution format — and, since the integer
//! rewrite, the *fast* format. The dense path dequantizes every layer
//! back to f32 before the graph runs; here the forward pass consumes
//! [`PackedLayer`]s as-is:
//!
//! - [`qmatmul`] quantizes the activations to `i8` once per call —
//!   per-row symmetric absmax, the A8 convention of the AOT activation
//!   graph (`s = absmax/127`, round-ties-even) — then walks the layer
//!   one tile-column panel at a time. Each tile's pre-expanded `i8`
//!   panel ([`crate::quant::packed::PackedTile::wq`]) is streamed
//!   against the `i8` activations by a 4-row register-blocked
//!   micro-kernel (the blocking scheme of [`super::kernels`]) that
//!   widens `i8 × i8 → i32` and accumulates in `i32`; one f32 rescale
//!   per `(row, tile)` (`tile.scale * layer.qstep * row_scale`) lands
//!   the partial sum in the output. The constant-trip inner loop over
//!   the tile width is written for LLVM's autovectorizer: a broadcast
//!   activation times a contiguous `i8` weight row, i.e. SIMD integer
//!   multiply-accumulates on every lane width the target offers. Panels
//!   are fanned out over the worker pool; each task owns disjoint
//!   output columns, `k` ascends, and per-tile sums are exact integers,
//!   so results are deterministic and thread-count independent.
//! - Weight traffic drops 4× vs dense f32 (1 byte/weight, no per-call
//!   LUT expansion — the PR 4 kernel re-materialized every panel as f32
//!   each call, which is why it ran ~0.55× dense).
//! - The f32 LUT kernel survives behind [`set_force_lut`] as the
//!   equivalence **oracle**: it expands the same integer codebook to an
//!   f32 panel per call and accumulates in f32. Because a tile edge is
//!   capped at [`crate::quant::packed::MAX_TILE`], every partial sum on
//!   both paths is an integer below 2^24, so the two paths are
//!   **bit-identical** — pinned by `tests/qexec.rs` and the greedy
//!   chains in `tests/decode_equiv.rs`.
//! - The `< 0.5 %` outlier/salient side matrix lands via
//!   [`crate::quant::sparse::SparseMatrix::spmv_into`] **after** the
//!   integer accumulation, on the original f32 activations — a fused
//!   epilogue, not a scatter into a dense copy.
//! - [`QCost`] prices every tile at its DVFS class frequency
//!   ([`crate::mac::MacProfile`] classes mapped onto a
//!   [`crate::dvfs::Ladder`]), giving the modeled speedup/energy that the
//!   serving CLI reports alongside wall-clock throughput.
//!
//! [`PackedModel`] is the parameter store for this path: packed tiles for
//! every linear weight, dense data only for the non-linear parameters
//! (embeddings, norms, biases). It never materializes a dense f32 linear
//! weight — [`PackedModel::dense_linear_count`] exists so tests can assert
//! exactly that.

#![warn(missing_docs)]

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::dvfs::{FreqClass, Ladder, Schedule};
use crate::mac::MacProfile;
use crate::quant::packed::{PackedLayer, PackedTile, TABLE_LEN};
use crate::quant::{HaloConfig, HaloQuantizer, LayerCtx, Matrix, Variant};
use crate::util::parallel;

use super::artifacts::ModelArtifacts;
use super::kvcache::{DecodeState, KvCache};
use super::sim::{self, ModelSpec, ParamSource};

/// Output rows accumulated together per micro-kernel pass (register
/// blocking factor, mirroring `runtime::kernels::MR`).
const MR: usize = 4;

/// Below this many MACs the panel fan-out costs more than it saves; run
/// the tile columns serially (mirrors `kernels::PAR_MIN_MACS`).
const PAR_MIN_MACS: usize = 1 << 17;

static FORCE_LUT: AtomicBool = AtomicBool::new(false);

/// Route [`qmatmul`] through the f32 LUT oracle kernel instead of the
/// integer path. The oracle expands the same `i8` codebook to an f32
/// panel per call and accumulates in f32 — every partial sum on both
/// paths is an integer below 2^24 ([`crate::quant::packed::MAX_TILE`]),
/// so the two are bit-identical; this switch exists for the equivalence
/// suites and differential benchmarking, never for serving.
pub fn set_force_lut(on: bool) {
    FORCE_LUT.store(on, Ordering::Relaxed);
}

/// Whether [`set_force_lut`] routing is currently active.
pub fn force_lut() -> bool {
    FORCE_LUT.load(Ordering::Relaxed)
}

/// Serializes tests that toggle [`set_force_lut`] and assert on which
/// path ran — without it a concurrent toggle makes an equivalence check
/// vacuously compare a path against itself. (Results are bit-identical
/// either way, so serving correctness never depends on this lock.)
pub static LUT_TEST_LOCK: Mutex<()> = Mutex::new(());

/// `y = x @ W` executed natively on a packed layer — integer W4A8 tile
/// kernels with the outliers fused as an SpMV epilogue. `x` is `(m, K)`
/// row-major; the result is `(m, N)`.
///
/// The activations are quantized to `i8` once per call (per-row
/// symmetric absmax — the A8 convention of the AOT activation graph);
/// each tile then accumulates `wq(i8) × xq(i8)` into `i32` and lands in
/// the f32 output through a single per-`(row, tile)` rescale
/// (`tile.scale * layer.qstep * row_scale`). The sparse outlier epilogue
/// runs on the *original* f32 activations.
///
/// Bit-for-bit deterministic: per-tile sums are exact integers (bounded
/// by the [`crate::quant::packed::MAX_TILE`] budget), tiles combine in
/// ascending `k` order, and the parallel panel tasks own disjoint
/// columns — so results are independent of blocking and thread count,
/// and identical between full-window and incremental calls (activation
/// quantization is row-local).
pub fn qmatmul(x: &Matrix, layer: &PackedLayer) -> Matrix {
    assert_eq!(
        x.cols,
        layer.rows(),
        "qmatmul: inner dims {} vs {} ({})",
        x.cols,
        layer.rows(),
        layer.name
    );
    let (m, n) = (x.rows, layer.cols());
    let grid = layer.grid;
    let mut out = Matrix::zeros(m, n);
    if m == 0 || n == 0 || layer.rows() == 0 {
        return out;
    }

    // One A8 pass over the activations, shared read-only by every panel
    // task. Row-local, so incremental and full-window calls quantize
    // identical rows identically (the decode-equivalence bit-exactness).
    let (xq, xs) = quantize_rows(x);
    let xk = x.cols;
    let oracle = force_lut();
    // Oracle-only: the integer codebook as f32, expanded per call like
    // the PR 4 LUT kernel.
    let qlut: [f32; TABLE_LEN] = std::array::from_fn(|j| layer.qtable[j] as f32);

    let panel_task = |tc: usize| -> Vec<f32> {
        let c0 = tc * grid.tile;
        let nw = (c0 + grid.tile).min(n) - c0;
        let mut y = vec![0.0f32; m * nw];
        let mut acc = vec![0i32; MR * nw];
        let mut facc = if oracle { vec![0.0f32; MR * nw] } else { Vec::new() };
        let mut wbuf = if oracle { vec![0.0f32; grid.tile * nw] } else { Vec::new() };
        for tr in 0..grid.tiles_r {
            let tile = &layer.tiles[tr * grid.tiles_c + tc];
            debug_assert_eq!(tile.cols, nw);
            let (k0, kh) = (tr * grid.tile, tile.rows);
            let rescale = tile.scale * layer.qstep;
            if oracle {
                for (wv, &code) in wbuf[..kh * nw].iter_mut().zip(tile.codes.iter()) {
                    *wv = qlut[code as usize];
                }
                lut_panel(&xq, &xs, xk, k0, kh, &wbuf[..kh * nw], nw, rescale, &mut facc, &mut y, m);
            } else {
                int_panel(&xq, &xs, xk, k0, kh, tile, nw, rescale, &mut acc, &mut y, m);
            }
        }
        y
    };

    let work = m * layer.rows() * n;
    let panels: Vec<Vec<f32>> = if work < PAR_MIN_MACS {
        (0..grid.tiles_c).map(panel_task).collect()
    } else {
        parallel::par_map(grid.tiles_c, panel_task)
    };
    for (tc, panel) in panels.into_iter().enumerate() {
        let c0 = tc * grid.tile;
        let nw = (c0 + grid.tile).min(n) - c0;
        for r in 0..m {
            out.row_mut(r)[c0..c0 + nw].copy_from_slice(&panel[r * nw..(r + 1) * nw]);
        }
    }

    // Fused epilogue: the hypersparse side matrix adds straight into the
    // output, from the original f32 activations — the dense weight plane
    // is never reconstructed.
    layer.sparse.spmv_into(x, &mut out);
    out
}

/// Per-row symmetric absmax quantization of the activations to `i8` —
/// the A8 convention of the AOT activation graph (`sim::fake_quant_rows`):
/// `s = absmax / 127` (1.0 for an all-zero row, so the codes stay 0),
/// `q = clamp(round_ties_even(v / s), -128, 127)`. Returns the `(m, K)`
/// code plane and the per-row scale.
fn quantize_rows(x: &Matrix) -> (Vec<i8>, Vec<f32>) {
    let (m, k) = (x.rows, x.cols);
    let mut xq = vec![0i8; m * k];
    let mut xs = vec![0.0f32; m];
    for r in 0..m {
        let row = x.row(r);
        let amax = row.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let s = if amax == 0.0 { 1.0 } else { amax / 127.0 };
        xs[r] = s;
        for (q, &v) in xq[r * k..(r + 1) * k].iter_mut().zip(row.iter()) {
            *q = (v / s).round_ties_even().clamp(-128.0, 127.0) as i8;
        }
    }
    (xq, xs)
}

/// Integer micro-kernel for one tile: `acc[(rows, nw)] = Σ_k wq · xq` in
/// `i32`, then one f32 rescale per row into `y`. 4-row register blocking
/// mirrors the dense kernel; the constant-trip inner loop — a broadcast
/// `i32`-widened activation times a contiguous `i8` weight row — is the
/// shape LLVM autovectorizes into SIMD widening multiply-accumulates.
/// `k` ascends and per-tile sums are exact integers, so the result is
/// independent of blocking and thread count.
#[allow(clippy::too_many_arguments)]
fn int_panel(
    xq: &[i8],
    xs: &[f32],
    xk: usize,
    k0: usize,
    kh: usize,
    tile: &PackedTile,
    nw: usize,
    rescale: f32,
    acc: &mut [i32],
    y: &mut [f32],
    m: usize,
) {
    let wq = &tile.wq;
    let mut r = 0usize;
    while r + MR <= m {
        acc[..MR * nw].fill(0);
        let (a01, a23) = acc.split_at_mut(2 * nw);
        let (acc0, acc1) = a01.split_at_mut(nw);
        let (acc2, acc3) = a23.split_at_mut(nw);
        for kk in 0..kh {
            let a0 = xq[r * xk + k0 + kk] as i32;
            let a1 = xq[(r + 1) * xk + k0 + kk] as i32;
            let a2 = xq[(r + 2) * xk + k0 + kk] as i32;
            let a3 = xq[(r + 3) * xk + k0 + kk] as i32;
            let wrow = &wq[kk * nw..(kk + 1) * nw];
            for (j, &wv) in wrow.iter().enumerate() {
                let w = wv as i32;
                acc0[j] += a0 * w;
                acc1[j] += a1 * w;
                acc2[j] += a2 * w;
                acc3[j] += a3 * w;
            }
        }
        for (rr, accr) in [&*acc0, &*acc1, &*acc2, &*acc3].into_iter().enumerate() {
            let rs = rescale * xs[r + rr];
            let yrow = &mut y[(r + rr) * nw..(r + rr + 1) * nw];
            for (o, &a) in yrow.iter_mut().zip(accr.iter()) {
                *o += a as f32 * rs;
            }
        }
        r += MR;
    }
    while r < m {
        let acc0 = &mut acc[..nw];
        acc0.fill(0);
        for kk in 0..kh {
            let a0 = xq[r * xk + k0 + kk] as i32;
            if a0 == 0 {
                continue;
            }
            let wrow = &wq[kk * nw..(kk + 1) * nw];
            for (j, &wv) in wrow.iter().enumerate() {
                acc0[j] += a0 * wv as i32;
            }
        }
        let rs = rescale * xs[r];
        let yrow = &mut y[r * nw..(r + 1) * nw];
        for (o, &a) in yrow.iter_mut().zip(acc0.iter()) {
            *o += a as f32 * rs;
        }
        r += 1;
    }
}

/// The f32 LUT oracle micro-kernel: identical loop structure and rescale
/// epilogue to [`int_panel`], but the quantized operands accumulate in
/// f32 against a per-call LUT-expanded panel (the PR 4 kernel shape).
/// Every product and partial sum is an integer below 2^24, so this is
/// bit-identical to the i32 path — which is the point: it is the oracle.
#[allow(clippy::too_many_arguments)]
fn lut_panel(
    xq: &[i8],
    xs: &[f32],
    xk: usize,
    k0: usize,
    kh: usize,
    w: &[f32],
    nw: usize,
    rescale: f32,
    facc: &mut [f32],
    y: &mut [f32],
    m: usize,
) {
    let mut r = 0usize;
    while r + MR <= m {
        facc[..MR * nw].fill(0.0);
        let (a01, a23) = facc.split_at_mut(2 * nw);
        let (acc0, acc1) = a01.split_at_mut(nw);
        let (acc2, acc3) = a23.split_at_mut(nw);
        for kk in 0..kh {
            let a0 = xq[r * xk + k0 + kk] as f32;
            let a1 = xq[(r + 1) * xk + k0 + kk] as f32;
            let a2 = xq[(r + 2) * xk + k0 + kk] as f32;
            let a3 = xq[(r + 3) * xk + k0 + kk] as f32;
            let wrow = &w[kk * nw..(kk + 1) * nw];
            for (j, &wv) in wrow.iter().enumerate() {
                acc0[j] += a0 * wv;
                acc1[j] += a1 * wv;
                acc2[j] += a2 * wv;
                acc3[j] += a3 * wv;
            }
        }
        for (rr, accr) in [&*acc0, &*acc1, &*acc2, &*acc3].into_iter().enumerate() {
            let rs = rescale * xs[r + rr];
            let yrow = &mut y[(r + rr) * nw..(r + rr + 1) * nw];
            for (o, &a) in yrow.iter_mut().zip(accr.iter()) {
                *o += a * rs;
            }
        }
        r += MR;
    }
    while r < m {
        let acc0 = &mut facc[..nw];
        acc0.fill(0.0);
        for kk in 0..kh {
            let a0 = xq[r * xk + k0 + kk] as f32;
            if a0 == 0.0 {
                continue;
            }
            let wrow = &w[kk * nw..(kk + 1) * nw];
            for (j, &wv) in wrow.iter().enumerate() {
                acc0[j] += a0 * wv;
            }
        }
        let rs = rescale * xs[r];
        let yrow = &mut y[r * nw..(r + 1) * nw];
        for (o, &a) in yrow.iter_mut().zip(acc0.iter()) {
            *o += a * rs;
        }
        r += 1;
    }
}

// ---------------------------------------------------------------- cost model

/// Per-tile cycle-cost model over one or more packed layers: every tile is
/// priced at its DVFS class frequency, the SpMV side at the base level on
/// its own engine (concurrent, like the systolic simulator's dataflow).
/// All times are per activation row, single-MAC-lane normalized — the
/// absolute scale cancels in the speedup/energy ratios this model exists
/// to report.
#[derive(Debug, Clone, Copy, Default)]
pub struct QCost {
    /// Modeled dense-tile time per activation row (s), tiles at class clocks.
    pub modeled_s: f64,
    /// The same work priced entirely at the base clock (the uniform-quant
    /// reference point).
    pub base_s: f64,
    /// SpMV engine time per activation row (s), base clock.
    pub spmv_s: f64,
    /// Dynamic MAC energy per activation row (pJ), V²-scaled per class.
    pub energy_pj: f64,
    /// Bytes the packed representation touches per pass.
    pub packed_bytes: usize,
    /// Bytes a dense f32 copy would touch per pass.
    pub dense_bytes: usize,
    /// Tiles per DVFS class, indexed by `FreqClass as usize`.
    pub class_tiles: [usize; 3],
    /// Live sparse entries routed to the SpMV engine.
    pub sparse_nnz: usize,
}

impl QCost {
    /// Accumulate the cost of `layer` under `ladder` clocks.
    pub fn add_layer(&mut self, layer: &PackedLayer, ladder: &Ladder) {
        let v_nom = crate::mac::power::V_NOM;
        for tile in &layer.tiles {
            let level = ladder.level(tile.class);
            let macs = tile.macs() as f64;
            self.modeled_s += macs / (level.ghz * 1e9);
            self.energy_pj += macs * tile.energy_pj * (level.volts / v_nom).powi(2);
            self.class_tiles[tile.class as usize] += 1;
        }
        let base = ladder.level(FreqClass::Base);
        self.base_s += layer.macs_per_row() as f64 / (base.ghz * 1e9);
        self.spmv_s += layer.sparse.nnz as f64 / (base.ghz * 1e9);
        self.packed_bytes += layer.packed_bytes();
        self.dense_bytes += layer.dense_bytes();
        self.sparse_nnz += layer.sparse.nnz;
    }

    /// Modeled speedup of class-clocked packed execution over the same
    /// MACs at the base clock (SpMV engine runs concurrently, so the
    /// slower stream bounds the pass).
    pub fn modeled_speedup(&self) -> f64 {
        self.base_s / self.modeled_s.max(self.spmv_s).max(1e-30)
    }

    /// Weight-traffic reduction: dense f32 bytes over packed bytes.
    pub fn bytes_saving(&self) -> f64 {
        self.dense_bytes as f64 / self.packed_bytes.max(1) as f64
    }

    /// One-line human summary for the serving CLI.
    pub fn summary(&self) -> String {
        format!(
            "modeled speedup {:.2}x vs base clock, bytes {:.2}x smaller ({} fast / {} med / {} base tiles, {} sparse nnz)",
            self.modeled_speedup(),
            self.bytes_saving(),
            self.class_tiles[FreqClass::Fast as usize],
            self.class_tiles[FreqClass::Med as usize],
            self.class_tiles[FreqClass::Base as usize],
            self.sparse_nnz
        )
    }
}

// ------------------------------------------------------------- packed store

/// Parameter store for native quantized execution: every linear weight as
/// a [`PackedLayer`], dense data only for embeddings/norms/biases. The
/// whole-model DVFS [`Schedule`] (class-clustered over all layers' tiles)
/// rides along for the serving executors.
#[derive(Debug)]
pub struct PackedModel {
    /// Transformer hyper-parameters + canonical parameter table.
    pub spec: ModelSpec,
    /// Non-linear parameters by name: (shape, flat data).
    dense: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    /// Packed quantized linear layers by name.
    layers: BTreeMap<String, PackedLayer>,
    /// Class-clustered DVFS schedule over every layer's tiles, in
    /// canonical layer order.
    pub schedule: Schedule,
}

impl PackedModel {
    /// Quantize and pack every linear parameter of `spec`. `params` yields
    /// borrowed `(name, shape, data)` views in any order (names must match
    /// the spec) — only one layer's dense weights are materialized at a
    /// time, so packing never doubles the resident model. `grads` supplies
    /// Fisher gradients for saliency/sensitivity where available.
    pub fn pack_from<'a>(
        spec: ModelSpec,
        params: impl IntoIterator<Item = (&'a str, &'a [usize], &'a [f32])>,
        variant: Variant,
        tile: usize,
        grads: &BTreeMap<String, Matrix>,
        profile: &MacProfile,
    ) -> Result<Self> {
        let q = HaloQuantizer::new(HaloConfig::new(tile, variant), profile);
        let mut dense = BTreeMap::new();
        let mut layers = BTreeMap::new();
        let mut classes = Vec::new();
        for (name, shape, data) in params {
            let i = spec
                .names
                .iter()
                .position(|n| n == name)
                .with_context(|| format!("parameter {name} not in model spec"))?;
            // Fail at pack time, not deep inside a shard's forward pass.
            anyhow::ensure!(
                shape == spec.shapes[i].as_slice(),
                "parameter {name}: shape {shape:?} != spec {:?}",
                spec.shapes[i]
            );
            anyhow::ensure!(
                data.len() == shape.iter().product::<usize>(),
                "parameter {name}: data length {} != shape {shape:?}",
                data.len()
            );
            if spec.linear[i] {
                anyhow::ensure!(shape.len() == 2, "linear parameter {name} is not 2-D");
                let w = Matrix::from_vec(shape[0], shape[1], data.to_vec());
                let ctx = match grads.get(name) {
                    Some(g) => LayerCtx::with_grad(name, g),
                    None => LayerCtx::new(name),
                };
                let (res, pay) = q.quantize_full(&w, &ctx);
                let packed = PackedLayer::pack(name, &res, &pay, profile);
                classes.extend(packed.classes());
                let prev = layers.insert(name.to_string(), packed);
                anyhow::ensure!(prev.is_none(), "duplicate parameter {name}");
            } else {
                let prev = dense.insert(name.to_string(), (shape.to_vec(), data.to_vec()));
                anyhow::ensure!(prev.is_none(), "duplicate parameter {name}");
            }
        }
        for (i, name) in spec.names.iter().enumerate() {
            let present = if spec.linear[i] {
                layers.contains_key(name)
            } else {
                dense.contains_key(name)
            };
            anyhow::ensure!(present, "model parameter {name} missing from pack input");
        }
        let schedule = Schedule::cluster(&classes);
        Ok(Self { spec, dense, layers, schedule })
    }

    /// Pack a trained model from the artifact store (the `halo serve
    /// --quant` path). Reads the spec from the sibling `config.json`;
    /// parameter data is borrowed, never bulk-cloned.
    pub fn pack_artifacts(
        model: &ModelArtifacts,
        variant: Variant,
        tile: usize,
        grads: &BTreeMap<String, Matrix>,
        profile: &MacProfile,
    ) -> Result<Self> {
        let spec = ModelSpec::load(&model.dir)?;
        let params = model
            .params
            .iter()
            .map(|p| (p.name.as_str(), p.shape.as_slice(), p.data.as_slice()));
        Self::pack_from(spec, params, variant, tile, grads, profile)
    }

    /// Logits for a `(b, s)` token batch, executed natively on the packed
    /// layers (codebook kernels + fused SpMV). Returns a `(b·s, vocab)`
    /// matrix.
    pub fn forward(&self, tokens: &[i32], b: usize, s: usize) -> Result<Matrix> {
        let src = PackedParams(self);
        let (logits, _, _) = sim::forward(&self.spec, &src, tokens, b, s, false)?;
        Ok(logits)
    }

    /// KV-cached incremental forward step, natively on the packed layers:
    /// evaluates only `tokens` (the window suffix at absolute positions
    /// `pos0..`), attending against — and appending to — `cache`. Every
    /// linear GEMM still routes through [`qmatmul`] + fused SpMV, so the
    /// packed path gets incremental decode from the shared interpreter
    /// for free (see [`sim::forward_incremental`]). Bit-identical to
    /// [`PackedModel::forward`] over the whole window, pinned by
    /// `tests/decode_equiv.rs`.
    pub fn forward_incremental(
        &self,
        tokens: &[i32],
        pos0: usize,
        cache: &mut KvCache,
    ) -> Result<Matrix> {
        let src = PackedParams(self);
        sim::forward_incremental(&self.spec, &src, tokens, pos0, cache, false)
    }

    /// Fresh, empty KV cache shaped for this model.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.spec.n_layers, self.spec.d_model)
    }

    /// Greedy (argmax) single-sequence decode on the packed layers,
    /// KV-cached — `max_new` tokens, sliding the context window at
    /// `seq_len` exactly like the serving decode loop: the first step
    /// prefills the window, every later step evaluates only the newest
    /// token, and a slide re-bases the cache instead of clearing it
    /// (ring positions; see `runtime::kvcache`). Bit-identical to the
    /// serving `QuantExecutor` path and, on chains that never slide, to
    /// [`PackedModel::decode_greedy_recompute`] (pinned by
    /// `tests/decode_equiv.rs`). The client-side oracle
    /// `halo loadgen --quant` re-derives sampled response chains against
    /// this.
    pub fn decode_greedy(&self, prefix: &[i32], max_new: usize) -> Result<Vec<i32>> {
        let mut s = DecodeState::with_cache(prefix, max_new, self.spec.seq_len, self.new_cache());
        while !s.done() {
            let (new, cached) = s.uncached_suffix()?;
            let t = if new.is_empty() {
                // Empty window (empty prefix): pad one position, same as
                // the recompute path, without touching the cache.
                let logits = self.forward(&[0], 1, 1)?;
                super::backend::argmax_slice(logits.row(0)) as i32
            } else {
                let logits = match s.cache_mut() {
                    Some(cache) => self.forward_incremental(&new, cached, cache)?,
                    None => anyhow::bail!("decode state constructed with a cache lost it"),
                };
                super::backend::argmax_slice(logits.row(new.len() - 1)) as i32
            };
            s.push_token(t);
        }
        Ok(s.into_generated())
    }

    /// Cache-free oracle decode: every step re-runs the whole live
    /// window through [`PackedModel::forward`]. O(S²) — kept as the
    /// differential oracle for the cached path (`halo loadgen --quant
    /// --no-kv-cache` verifies against this) and for chains where an
    /// independent recomputation is wanted.
    pub fn decode_greedy_recompute(&self, prefix: &[i32], max_new: usize) -> Result<Vec<i32>> {
        let cap = self.spec.seq_len;
        let mut seq: Vec<i32> = prefix[prefix.len().saturating_sub(cap)..].to_vec();
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            let n = seq.len().min(cap).max(1);
            let mut tokens = vec![0i32; n];
            let live = seq.len().min(n);
            tokens[..live].copy_from_slice(&seq[seq.len() - live..]);
            let logits = self.forward(&tokens, 1, n)?;
            let t = super::backend::argmax_slice(logits.row(n - 1)) as i32;
            out.push(t);
            if seq.len() >= cap {
                seq.remove(0);
            }
            seq.push(t);
        }
        Ok(out)
    }

    /// The packed layer for a linear parameter, if packed.
    pub fn layer(&self, name: &str) -> Option<&PackedLayer> {
        self.layers.get(name)
    }

    /// Iterate over every packed layer in name order.
    pub fn packed_layers(&self) -> impl Iterator<Item = &PackedLayer> {
        self.layers.values()
    }

    /// Number of packed (linear) layers.
    pub fn n_packed(&self) -> usize {
        self.layers.len()
    }

    /// Dense flat data for a non-linear parameter, if stored dense.
    pub fn dense_param(&self, name: &str) -> Option<&[f32]> {
        self.dense.get(name).map(|(_, d)| d.as_slice())
    }

    /// How many *linear* parameters are held as dense f32 — always 0: the
    /// store keeps linear weights only in packed form. Tests assert this
    /// to pin the never-densify guarantee.
    pub fn dense_linear_count(&self) -> usize {
        self.spec
            .names
            .iter()
            .enumerate()
            .filter(|(i, name)| self.spec.linear[*i] && self.dense.contains_key(*name))
            .count()
    }

    /// Aggregate per-tile cycle-cost model under `ladder` clocks.
    pub fn cost(&self, ladder: &Ladder) -> QCost {
        let mut c = QCost::default();
        for layer in self.layers.values() {
            c.add_layer(layer, ladder);
        }
        c
    }

    /// Materialize this packed model as an owned dense
    /// [`sim::DenseParams`] store: every packed linear layer is
    /// dequantized ([`PackedLayer::dequantize`]), everything else copied
    /// from the dense map. Since the integer W4A8 rewrite this is **not**
    /// the drafter path — packed decode is now faster than dense, so
    /// `coordinator::spec` drafts natively on the packed model — but the
    /// expansion stays as the dense-numerics oracle for tests and for
    /// callers that want the quantized weights under the dense kernels
    /// (within the A8 activation-quantization tolerance, see the
    /// `qmatmul_tracks_dequantize_then_dense` pin). The model's own
    /// never-densify store is untouched
    /// ([`PackedModel::dense_linear_count`] stays 0).
    pub fn expand_params(&self) -> Result<sim::DenseParams> {
        let mut owned: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
        for (i, name) in self.spec.names.iter().enumerate() {
            if self.spec.linear[i] {
                let layer = self
                    .layers
                    .get(name)
                    .with_context(|| format!("packed layer {name} missing"))?;
                let w = layer.dequantize();
                owned.push((name.clone(), vec![w.rows, w.cols], w.data));
            } else {
                let (shape, data) = self
                    .dense
                    .get(name)
                    .with_context(|| format!("dense parameter {name} missing"))?;
                owned.push((name.clone(), shape.clone(), data.clone()));
            }
        }
        sim::DenseParams::from_params(
            &self.spec,
            owned.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice())),
        )
    }
}

/// [`ParamSource`] adapter: dense lookups from the non-linear map, linear
/// GEMMs through [`qmatmul`]. `mat()` on a packed layer is an error by
/// design — that is the densification this engine exists to avoid.
struct PackedParams<'a>(&'a PackedModel);

impl ParamSource for PackedParams<'_> {
    fn vec1(&self, name: &str) -> Result<&[f32]> {
        self.0
            .dense_param(name)
            .ok_or_else(|| anyhow::anyhow!("missing dense parameter {name}"))
    }

    fn mat(&self, name: &str) -> Result<Matrix> {
        if self.0.layers.contains_key(name) {
            anyhow::bail!("{name} is packed; the quantized path never densifies it");
        }
        let (shape, data) = self
            .0
            .dense
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing parameter {name}"))?;
        anyhow::ensure!(shape.len() == 2, "parameter {name} is not 2-D: {shape:?}");
        Ok(Matrix::from_vec(shape[0], shape[1], data.clone()))
    }

    fn linmul(&self, x: &Matrix, name: &str) -> Result<Matrix> {
        let layer = self
            .0
            .layers
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing packed layer {name}"))?;
        Ok(qmatmul(x, layer))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kernels;
    use crate::util::Rng;

    fn packed_layer(rows: usize, cols: usize, tile: usize, seed: u64) -> (Matrix, PackedLayer) {
        let profile = MacProfile::cached();
        let mut rng = Rng::seed_from_u64(seed);
        let w = Matrix::random_normal(rows, cols, 0.02, &mut rng);
        let g = Matrix::random_normal(rows, cols, 1.0, &mut rng);
        let q = HaloQuantizer::new(HaloConfig::new(tile, Variant::Bal), profile);
        let (res, pay) = q.quantize_full(&w, &LayerCtx::with_grad("t", &g));
        (w, PackedLayer::pack("t", &res, &pay, profile))
    }

    #[test]
    fn qmatmul_tracks_dequantize_then_dense() {
        // The integer path quantizes activations to i8 and the codebook
        // to i8, so it *approximates* the dequantize-then-dense oracle
        // (A8 absmax error + half-a-qstep table error) instead of
        // matching it to summation order. The exact oracle for the
        // integer path is the LUT kernel (see
        // `integer_path_bit_identical_to_lut_oracle`).
        let mut rng = Rng::seed_from_u64(100);
        for (m, k, n, tile) in [(4, 32, 32, 16), (7, 96, 64, 32), (1, 64, 96, 32)] {
            let (_, layer) = packed_layer(k, n, tile, 200 + m as u64);
            let x = Matrix::random_normal(m, k, 1.0, &mut rng);
            let got = qmatmul(&x, &layer);
            let want = kernels::matmul(&x, &layer.dequantize());
            for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
                assert!(
                    (a - b).abs() <= 5e-2 * (1.0 + b.abs()),
                    "({m},{k},{n},t{tile})[{i}]: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn integer_path_bit_identical_to_lut_oracle() {
        let _guard = LUT_TEST_LOCK.lock().unwrap();
        let mut rng = Rng::seed_from_u64(900);
        for (m, k, n, tile) in [(5, 96, 64, 32), (1, 64, 96, 32), (3, 100, 70, 32)] {
            let (_, layer) = packed_layer(k, n, tile, 300 + m as u64);
            let x = Matrix::random_normal(m, k, 1.0, &mut rng);
            set_force_lut(false);
            let int_path = qmatmul(&x, &layer);
            set_force_lut(true);
            let oracle = qmatmul(&x, &layer);
            set_force_lut(false);
            assert_eq!(
                int_path.data, oracle.data,
                "i8 path must be bit-identical to the f32 LUT oracle ({m},{k},{n},t{tile})"
            );
        }
    }

    #[test]
    fn qmatmul_thread_count_independent() {
        let _guard = crate::util::parallel::THREAD_CAP_TEST_LOCK.lock().unwrap();
        let (_, layer) = packed_layer(128, 128, 32, 77);
        let mut rng = Rng::seed_from_u64(78);
        let x = Matrix::random_normal(16, 128, 1.0, &mut rng);
        let par = qmatmul(&x, &layer);
        crate::util::parallel::set_max_threads(1);
        let ser = qmatmul(&x, &layer);
        crate::util::parallel::set_max_threads(0);
        assert_eq!(par.data, ser.data, "qmatmul must be deterministic");
    }

    #[test]
    fn pack_from_rejects_bad_shapes_and_duplicates() {
        let spec = ModelSpec::synthetic(11, 8, 1, 2, 16, 6);
        let profile = MacProfile::cached();
        let grads = BTreeMap::new();
        let base: Vec<(String, Vec<usize>, Vec<f32>)> = spec
            .names
            .iter()
            .zip(&spec.shapes)
            .map(|(n, sh)| (n.clone(), sh.clone(), vec![0.01f32; sh.iter().product()]))
            .collect();
        let pack = |p: &[(String, Vec<usize>, Vec<f32>)]| {
            let views = p.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
            PackedModel::pack_from(spec.clone(), views, Variant::Bal, 4, &grads, profile)
        };

        assert!(pack(&base).is_ok());

        // Mis-shaped pos_embed must fail at pack time, not at serve time.
        let mut bad = base.clone();
        bad[1].1 = vec![3, 8];
        bad[1].2 = vec![0.01f32; 24];
        assert!(pack(&bad).is_err());

        // Duplicate parameter names must be rejected, not silently merged.
        let mut dup = base.clone();
        let first = dup[2].clone();
        dup.push(first);
        assert!(pack(&dup).is_err());
    }

    /// Seeded tiny packed model for the incremental / expansion pins.
    fn tiny_packed(seed: u64, variant: Variant) -> (ModelSpec, PackedModel) {
        let spec = ModelSpec::synthetic(11, 8, 1, 2, 16, 6);
        let profile = MacProfile::cached();
        let mut rng = Rng::seed_from_u64(seed);
        let mut params: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
        let mut grads = BTreeMap::new();
        for (i, (name, shape)) in spec.names.iter().zip(&spec.shapes).enumerate() {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if name.ends_with(".scale") {
                vec![1.0; n]
            } else {
                (0..n).map(|_| rng.gen_normal() as f32 * 0.1).collect()
            };
            if spec.linear[i] {
                grads.insert(
                    name.clone(),
                    Matrix::from_fn(shape[0], shape[1], |_, _| rng.gen_normal() as f32),
                );
            }
            params.push((name.clone(), shape.clone(), data));
        }
        let views = params.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice()));
        let pm = PackedModel::pack_from(spec.clone(), views, variant, 4, &grads, profile).unwrap();
        (spec, pm)
    }

    #[test]
    fn packed_incremental_matches_packed_full_bitexact() {
        // The packed path inherits incremental decode from the shared
        // interpreter: prefill + single-token steps must reproduce the
        // full-window logits rows exactly.
        let (spec, pm) = tiny_packed(321, Variant::Bal);
        let s = spec.seq_len;
        let toks: Vec<i32> = (0..s as i32).map(|t| (t * 5 + 2) % spec.vocab as i32).collect();
        let full = pm.forward(&toks, 1, s).unwrap();
        let mut cache = pm.new_cache();
        let pre = pm.forward_incremental(&toks[..2], 0, &mut cache).unwrap();
        assert_eq!(pre.row(0), full.row(0));
        assert_eq!(pre.row(1), full.row(1));
        for i in 2..s {
            let one = pm.forward_incremental(&toks[i..i + 1], i, &mut cache).unwrap();
            assert_eq!(one.row(0), full.row(i), "packed incremental step {i}");
        }
    }

    #[test]
    fn expand_params_tracks_packed_numerics() {
        // The dense expansion must track the packed chain's numerics up
        // to the integer path's A8 activation + i8 codebook error
        // (`qmatmul_tracks_dequantize_then_dense`), without densifying
        // the packed store itself.
        let (spec, pm) = tiny_packed(654, Variant::PerfOpt);
        let dp = pm.expand_params().unwrap();
        assert_eq!(pm.dense_linear_count(), 0, "expansion must not densify the store");

        let s = spec.seq_len;
        let toks: Vec<i32> = (0..s as i32).map(|t| (t * 3 + 1) % spec.vocab as i32).collect();
        let packed = pm.forward(&toks, 1, s).unwrap();
        let (dense, _, _) = sim::forward(&spec, &dp, &toks, 1, s, false).unwrap();
        assert_eq!((packed.rows, packed.cols), (dense.rows, dense.cols));
        for (i, (a, b)) in packed.data.iter().zip(&dense.data).enumerate() {
            assert!(
                (a - b).abs() <= 8e-2 * (1.0 + b.abs()),
                "expanded logits diverge at [{i}]: packed {a} vs expanded {b}"
            );
        }
    }

    #[test]
    fn cost_model_speedup_and_bytes() {
        let (_, layer) = packed_layer(128, 128, 32, 5);
        let mut c = QCost::default();
        c.add_layer(&layer, &Ladder::paper_systolic());
        // Codebook-pure tiles clock above base: strict modeled speedup.
        assert!(c.modeled_speedup() > 1.0, "{}", c.modeled_speedup());
        assert!(c.modeled_speedup() <= 3.7 / 1.9 + 1e-9);
        assert!(c.bytes_saving() > 3.0, "{}", c.bytes_saving());
        let tiles: usize = c.class_tiles.iter().sum();
        assert_eq!(tiles, layer.tiles.len());
        assert!(c.energy_pj > 0.0);
    }
}
