//! Runtime + artifact store: everything the L3 binary needs to load and
//! execute the AOT-lowered L1/L2 graphs. Python never runs here.
//!
//! Execution goes through a pluggable [`Backend`]: the pure-Rust
//! [`sim::SimBackend`] interpreter by default (always buildable offline),
//! or the PJRT/XLA path when compiled with `--features xla` (see
//! `DESIGN.md` §Backends).

pub mod artifacts;
pub mod backend;
pub mod client;
pub mod kernels;
pub mod kvcache;
pub mod qkernels;
pub mod sample;
pub mod sim;
#[cfg(feature = "xla")]
pub mod xla;

pub use artifacts::{ModelArtifacts, Param, Store};
pub use backend::{argmax_slice, Backend, Buffer, Literal, LiteralData};
pub use client::{literal_f32, literal_i32, literal_i8, Executable, Runtime};
pub use kvcache::{BlockPool, DecodeState, KvCache, PoolExhausted, PoolStats, DEFAULT_BLOCK_ROWS};
pub use qkernels::{qmatmul, PackedModel, QCost};
pub use sample::{Sampler, SamplingParams};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_batches_shape() {
        let stream: Vec<u16> = (0..1000).map(|i| (i % 256) as u16).collect();
        let b = artifacts::nll_batches(&stream, 2, 9);
        assert_eq!(b.len(), 50);
        assert_eq!(b[0].len(), 20);
        assert_eq!(b[0][0], 0);
    }
}
