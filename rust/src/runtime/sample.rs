//! Seeded token sampling (PR 9): temperature / top-k selection over
//! f64-softmaxed logits, driven by the deterministic [`crate::util::Rng`].
//!
//! Decode was argmax-only before speculative decoding landed; speculative
//! acceptance under sampling needs a *seeded* per-request RNG so that a
//! speculative chain and a verifier-only chain consume bit-identical
//! random draws. The contract that makes both reproducible:
//!
//! - All probability math is f64 (logits are f32): softmax in a fixed
//!   order over the candidate set, so the selection is exactly
//!   reproducible across thread counts and shard layouts.
//! - **One RNG draw per emitted token**, and only for emitted tokens.
//!   Drafter proposals are always greedy (argmax) and never touch the
//!   RNG, so a speculative chain draws the same stream as a sequential
//!   verifier-only chain emitting the same tokens.
//! - `temperature == 0` is exact greedy: it selects via
//!   [`super::backend::argmax_slice`] and draws nothing, matching the
//!   argmax decode paths bit for bit.
//!
//! Sampling applies on the incremental (KV-cached) executor paths, which
//! see per-position logits; the recompute oracle paths stay argmax.

use super::backend::argmax_slice;
use crate::util::Rng;

/// Per-request sampling controls, carried on the `Request` builder and
/// attached to the request's `DecodeState` by the shard loop.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature. `0` (or any non-finite / non-positive value)
    /// means exact greedy decode (no RNG draw).
    pub temperature: f64,
    /// Restrict sampling to the `top_k` highest-logit tokens; `0` means
    /// the full vocabulary.
    pub top_k: usize,
    /// Seed of the per-request RNG stream.
    pub seed: u64,
}

impl SamplingParams {
    /// Sampling at `temperature` 1.0 over the full vocabulary with the
    /// given seed; tune with [`SamplingParams::temperature`] /
    /// [`SamplingParams::top_k`].
    pub fn new(seed: u64) -> Self {
        Self { temperature: 1.0, top_k: 0, seed }
    }

    /// Set the softmax temperature (`0` = exact greedy).
    pub fn temperature(mut self, t: f64) -> Self {
        self.temperature = t;
        self
    }

    /// Restrict to the `k` highest-logit tokens (`0` = full vocabulary).
    pub fn top_k(mut self, k: usize) -> Self {
        self.top_k = k;
        self
    }

    /// True when these params reduce to greedy argmax (no RNG use).
    pub fn is_greedy(&self) -> bool {
        !(self.temperature.is_finite() && self.temperature > 0.0)
    }
}

/// A seeded sampler: [`SamplingParams`] plus the request's RNG stream.
/// One lives on each sampled request's `DecodeState`; cloning it forks
/// the stream (used only by oracle replays in tests).
#[derive(Debug, Clone)]
pub struct Sampler {
    params: SamplingParams,
    rng: Rng,
}

impl Sampler {
    /// Sampler at the start of its seeded stream.
    pub fn new(params: SamplingParams) -> Self {
        Self { params, rng: Rng::seed_from_u64(params.seed) }
    }

    /// The sampling controls this sampler was built with.
    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    /// Select the next token from one row of logits. Exactly one RNG
    /// draw when sampling; zero draws (plain argmax) when greedy, when
    /// the row is empty, or when the softmax mass is degenerate (all
    /// candidate weights zero / non-finite — the top-ranked candidate
    /// wins deterministically).
    pub fn select(&mut self, logits: &[f32]) -> usize {
        if self.params.is_greedy() || logits.len() <= 1 {
            return argmax_slice(logits);
        }
        // Candidate set: indices of the top-k logits (ties broken toward
        // lower indices), or everything when top_k is 0 / oversized.
        let k = match self.params.top_k {
            0 => logits.len(),
            k => k.min(logits.len()),
        };
        let mut order: Vec<usize> = (0..logits.len()).collect();
        order.sort_unstable_by(|&a, &b| {
            logits[b].total_cmp(&logits[a]).then(a.cmp(&b))
        });
        order.truncate(k);
        // f64 softmax over the candidates in their (deterministic)
        // logit-descending order, then one inverse-CDF draw.
        let t = self.params.temperature;
        let m = f64::from(logits[order[0]]);
        let weights: Vec<f64> = order
            .iter()
            .map(|&i| ((f64::from(logits[i]) - m) / t).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        // Degenerate mass: every candidate weight underflowed to zero,
        // or a non-finite logit poisoned the softmax (±inf/NaN make
        // `total` NaN, so no inverse-CDF bin can ever fire and the tail
        // fallback would return the *lowest*-ranked candidate). Take the
        // top-ranked candidate and draw nothing — deterministic on both
        // the speculative and verifier-only paths, so streams stay
        // aligned.
        if total == 0.0 || !total.is_finite() {
            return order[0];
        }
        let u = self.rng.gen_f64() * total;
        let mut acc = 0.0;
        for (i, w) in order.iter().zip(&weights) {
            acc += w;
            if u < acc {
                return *i;
            }
        }
        // Float round-off at the tail: the last candidate wins.
        order[k - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits() -> Vec<f32> {
        vec![0.1, 2.0, -1.0, 1.9, 0.5, 2.0]
    }

    #[test]
    fn zero_temperature_is_argmax_and_draws_nothing() {
        let mut s = Sampler::new(SamplingParams::new(7).temperature(0.0));
        let mut t = Sampler::new(SamplingParams::new(7).temperature(0.0));
        for _ in 0..5 {
            assert_eq!(s.select(&logits()), argmax_slice(&logits()));
        }
        // The stream was never consumed: both samplers still agree with a
        // fresh one after any number of greedy selections.
        assert_eq!(s.rng.next_u64(), t.rng.next_u64());
    }

    #[test]
    fn seeded_stream_is_reproducible_and_seed_sensitive() {
        let p = SamplingParams::new(42).temperature(0.8).top_k(4);
        let mut a = Sampler::new(p);
        let mut b = Sampler::new(p);
        let picks_a: Vec<usize> = (0..64).map(|_| a.select(&logits())).collect();
        let picks_b: Vec<usize> = (0..64).map(|_| b.select(&logits())).collect();
        assert_eq!(picks_a, picks_b);
        let mut c = Sampler::new(SamplingParams::new(43).temperature(0.8).top_k(4));
        let picks_c: Vec<usize> = (0..64).map(|_| c.select(&logits())).collect();
        assert_ne!(picks_a, picks_c, "different seeds should diverge");
    }

    #[test]
    fn top_k_restricts_support() {
        // top_k = 2 keeps only the two 2.0 logits (indices 1 and 5).
        let mut s = Sampler::new(SamplingParams::new(9).temperature(5.0).top_k(2));
        for _ in 0..256 {
            let pick = s.select(&logits());
            assert!(pick == 1 || pick == 5, "pick {pick} outside top-2 support");
        }
    }

    #[test]
    fn high_temperature_covers_full_support() {
        let mut s = Sampler::new(SamplingParams::new(3).temperature(10.0));
        let mut seen = [false; 6];
        for _ in 0..2048 {
            seen[s.select(&logits())] = true;
        }
        assert!(seen.iter().all(|&x| x), "full-vocab sampling missed a token: {seen:?}");
    }

    #[test]
    fn extreme_temperature_returns_top_ranked_candidate() {
        // t = 1e-300: every non-max candidate weight underflows to zero.
        // The pick must be the top-ranked candidate (the argmax), never
        // the `order[k-1]` tail fallback.
        let lg = vec![0.1, 2.0, -1.0, 1.9, 0.5];
        let mut s = Sampler::new(SamplingParams::new(11).temperature(1e-300));
        for _ in 0..32 {
            assert_eq!(s.select(&lg), 1, "tiny-temperature pick must be the argmax");
        }
    }

    #[test]
    fn non_finite_logits_fall_back_to_top_ranked_not_tail() {
        let p = SamplingParams::new(13).temperature(0.7);
        let mut s = Sampler::new(p);
        let mut fresh = Sampler::new(p);
        // +inf max: (inf - inf)/t is NaN, the softmax total is NaN, and
        // no inverse-CDF bin can fire — before the guard this returned
        // the lowest-ranked candidate.
        assert_eq!(s.select(&[0.0, f32::INFINITY, -1.0]), 1);
        // All -inf: (-inf) - (-inf) is NaN again; top-ranked is index 0
        // by the deterministic tie order.
        assert_eq!(s.select(&[f32::NEG_INFINITY; 4]), 0);
        // Degenerate selections are deterministic and draw nothing.
        assert_eq!(s.rng.next_u64(), fresh.rng.next_u64(), "guarded selects must not draw");
    }

    #[test]
    fn low_temperature_concentrates_on_argmax() {
        let mut s = Sampler::new(SamplingParams::new(5).temperature(1e-3));
        for _ in 0..64 {
            // Ties on the max logit (indices 1 and 5) split the mass; both
            // are valid, everything else has ~zero probability.
            let pick = s.select(&logits());
            assert!(pick == 1 || pick == 5, "pick {pick} at near-zero temperature");
        }
    }
}
