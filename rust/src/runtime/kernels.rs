//! Blocked, thread-parallel f32 matmul micro-kernels for the pure-Rust
//! backend's GEMM-shaped hot paths (forward, attention projections, and
//! the whole gradient path).
//!
//! One register-blocked kernel serves all three layouts. [`matmul`]
//! processes `MR` output rows per pass so every streamed row of `B` is
//! reused `MR`× from registers, walks the output in `NC`-wide column
//! panels so the accumulator rows stay L1-resident, and parallelizes over
//! output row blocks ([`crate::util::parallel::par_chunks_mut`] — each
//! thread owns disjoint rows, so results are deterministic). The TN/NT
//! layouts pack the non-streaming operand into a transposed panel first
//! ([`transpose`]) and reuse the same kernel, which also preserves the
//! per-element summation order of the naive implementations (ascending
//! `k`), keeping results bit-for-bit reproducible.
//!
//! The seed implementations live on in [`naive`] as the equivalence
//! oracles for `tests/hotpaths.rs` and the pre-PR baseline for
//! `benches/l1_hotpaths.rs`; [`set_force_naive`] routes the public entry
//! points through them for differential benchmarking.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::quant::Matrix;
use crate::util::parallel;

/// Output rows per register-blocked micro-kernel pass.
const MR: usize = 4;
/// Output-column panel width: MR accumulator rows × NC f32 ≤ 32 KiB (L1).
const NC: usize = 2048;
/// Below this many MACs the thread fan-out costs more than it saves
/// (spawn/join ≫ compute for the unit-test-sized GEMMs); run serial.
const PAR_MIN_MACS: usize = 1 << 17;

static FORCE_NAIVE: AtomicBool = AtomicBool::new(false);

/// Route [`matmul`]/[`matmul_tn`]/[`matmul_nt`] through the seed
/// implementations (pre-PR baseline measurements; equivalence tests).
pub fn set_force_naive(on: bool) {
    FORCE_NAIVE.store(on, Ordering::Relaxed);
}

/// Whether [`set_force_naive`] routing is currently active.
pub fn force_naive() -> bool {
    FORCE_NAIVE.load(Ordering::Relaxed)
}

/// `a @ b` for a (m, k), b (k, n) → (m, n).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul: inner dims {} vs {}", a.cols, b.rows);
    if force_naive() {
        return naive::matmul(a, b);
    }
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = Matrix::zeros(m, n);
    if m == 0 || k == 0 || n == 0 {
        return out;
    }
    let (a_data, b_data) = (a.data.as_slice(), b.data.as_slice());
    if m * k * n < PAR_MIN_MACS {
        for (tile, chunk) in out.data.chunks_mut(MR * n).enumerate() {
            block_rows(a_data, b_data, k, n, tile * MR, chunk);
        }
    } else {
        parallel::par_chunks_mut(&mut out.data, MR * n, |tile, chunk| {
            block_rows(a_data, b_data, k, n, tile * MR, chunk);
        });
    }
    out
}

/// `aᵀ @ b` for a (n, r), b (n, c) → (r, c). Weight-gradient layout
/// (`dW = xᵀ @ dy`): packs `aᵀ` and reuses the blocked kernel.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_tn: outer dims {} vs {}", a.rows, b.rows);
    if force_naive() {
        return naive::matmul_tn(a, b);
    }
    matmul(&transpose(a), b)
}

/// `a @ bᵀ` for a (n, c), b (m, c) → (n, m). Gradient pushback layout
/// (`dx = dy @ Wᵀ`): packs `bᵀ` and reuses the blocked kernel.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_nt: inner dims {} vs {}", a.cols, b.cols);
    if force_naive() {
        return naive::matmul_nt(a, b);
    }
    matmul(a, &transpose(b))
}

/// Blocked transpose — the packing step for the TN/NT layouts.
pub fn transpose(a: &Matrix) -> Matrix {
    const TB: usize = 32;
    let mut out = Matrix::zeros(a.cols, a.rows);
    for r0 in (0..a.rows).step_by(TB) {
        let r1 = (r0 + TB).min(a.rows);
        for c0 in (0..a.cols).step_by(TB) {
            let c1 = (c0 + TB).min(a.cols);
            for r in r0..r1 {
                for c in c0..c1 {
                    out.data[c * a.rows + r] = a.data[r * a.cols + c];
                }
            }
        }
    }
    out
}

/// One output row block: `chunk` holds `chunk.len() / n` rows of the
/// output starting at row `i0`. Walks `NC`-wide column panels; within a
/// panel, `MR = 4` rows accumulate together so each streamed `b` row is
/// reused 4× (plus a tail loop for the last `rows % 4`).
fn block_rows(a: &[f32], b: &[f32], k: usize, n: usize, i0: usize, chunk: &mut [f32]) {
    let rows = chunk.len() / n;
    let mut j0 = 0usize;
    while j0 < n {
        let jw = (n - j0).min(NC);
        let mut r = 0usize;
        while r + MR <= rows {
            let i = i0 + r;
            let (r01, r23) = chunk[r * n..(r + MR) * n].split_at_mut(2 * n);
            let (row0, row1) = r01.split_at_mut(n);
            let (row2, row3) = r23.split_at_mut(n);
            let o0 = &mut row0[j0..j0 + jw];
            let o1 = &mut row1[j0..j0 + jw];
            let o2 = &mut row2[j0..j0 + jw];
            let o3 = &mut row3[j0..j0 + jw];
            for kk in 0..k {
                let a0 = a[i * k + kk];
                let a1 = a[(i + 1) * k + kk];
                let a2 = a[(i + 2) * k + kk];
                let a3 = a[(i + 3) * k + kk];
                let brow = &b[kk * n + j0..kk * n + j0 + jw];
                for (j, &bv) in brow.iter().enumerate() {
                    o0[j] += a0 * bv;
                    o1[j] += a1 * bv;
                    o2[j] += a2 * bv;
                    o3[j] += a3 * bv;
                }
            }
            r += MR;
        }
        while r < rows {
            let i = i0 + r;
            let orow = &mut chunk[r * n + j0..r * n + j0 + jw];
            for kk in 0..k {
                let av = a[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n + j0..kk * n + j0 + jw];
                for (j, &bv) in brow.iter().enumerate() {
                    orow[j] += av * bv;
                }
            }
            r += 1;
        }
        j0 += jw;
    }
}

/// Dot product with four independent accumulators: serial f32 adds form a
/// dependency chain the compiler may not reassociate, so splitting the sum
/// exposes ILP/SIMD while staying deterministic. Used by the attention
/// logits and gradient reductions in `runtime::sim`.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() & !3;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut i = 0usize;
    while i < n4 {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = (s0 + s2) + (s1 + s3);
    while i < a.len() {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// The seed implementations, kept verbatim as equivalence oracles and the
/// pre-PR baseline (`benches/l1_hotpaths.rs`).
pub mod naive {
    use crate::quant::Matrix;

    /// Single-pass `a @ b` (the seed `Matrix::matmul`).
    pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
        a.matmul(b)
    }

    /// aᵀ @ b for a (n, r), b (n, c) → (r, c).
    pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.rows, b.rows);
        let mut out = Matrix::zeros(a.cols, b.cols);
        for k in 0..a.rows {
            let arow = a.row(k);
            let brow = b.row(k);
            for (i, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                for (j, &bv) in brow.iter().enumerate() {
                    orow[j] += av * bv;
                }
            }
        }
        out
    }

    /// a @ bᵀ for a (n, c), b (m, c) → (n, m).
    pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.cols);
        let mut out = Matrix::zeros(a.rows, b.rows);
        for i in 0..a.rows {
            let arow = a.row(i);
            let orow = out.row_mut(i);
            for j in 0..b.rows {
                let brow = b.row(j);
                let mut acc = 0.0f32;
                for (x, y) in arow.iter().zip(brow) {
                    acc += x * y;
                }
                orow[j] = acc;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn assert_close(got: &Matrix, want: &Matrix, what: &str) {
        assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{what}: shape");
        for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                "{what}[{i}]: {a} vs {b}"
            );
        }
    }

    #[test]
    fn blocked_matmul_small_exact() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        assert_eq!(matmul(&a, &b).data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seed_from_u64(1);
        let a = Matrix::random_normal(37, 53, 1.0, &mut rng);
        let t = transpose(&a);
        assert_eq!((t.rows, t.cols), (53, 37));
        assert_eq!(transpose(&t), a);
    }

    #[test]
    fn blocked_matches_naive_on_ragged_shapes() {
        // Shapes deliberately not divisible by MR / the panel width.
        let mut rng = Rng::seed_from_u64(42);
        for case in 0..12 {
            let m = 1 + rng.gen_usize(37);
            let k = 1 + rng.gen_usize(45);
            let n = 1 + rng.gen_usize(41);
            let a = Matrix::random_normal(m, k, 1.0, &mut rng);
            let b = Matrix::random_normal(k, n, 1.0, &mut rng);
            assert_close(&matmul(&a, &b), &naive::matmul(&a, &b), &format!("mm case {case}"));

            let at = Matrix::random_normal(k, m, 1.0, &mut rng);
            assert_close(
                &matmul_tn(&at, &b),
                &naive::matmul_tn(&at, &b),
                &format!("tn case {case}"),
            );

            let bt = Matrix::random_normal(n, k, 1.0, &mut rng);
            assert_close(
                &matmul_nt(&a, &bt),
                &naive::matmul_nt(&a, &bt),
                &format!("nt case {case}"),
            );
        }
    }

    #[test]
    fn dot_matches_serial_sum() {
        let mut rng = Rng::seed_from_u64(9);
        for len in [0usize, 1, 3, 4, 7, 64, 129] {
            let a: Vec<f32> = (0..len).map(|_| rng.gen_normal() as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.gen_normal() as f32).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() <= 1e-4 * (1.0 + want.abs()), "len {len}");
        }
    }
}
