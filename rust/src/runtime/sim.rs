//! `SimBackend`: a pure-Rust, dense-f32 interpreter of the AOT artifacts.
//!
//! The offline build cannot compile HLO (no XLA), but it does not need to:
//! every lowered graph is one of a small closed set produced by
//! `python/compile/aot.py` (`nll_fp` / `nll_a8` / `fwd_fp` / `grad` per
//! model, plus the standalone `halo_matmul` / `spmv` kernels). This backend
//! recognizes the graph by artifact name, reads the model hyper-parameters
//! from the sibling `config.json` / `kernels.json`, and evaluates the same
//! computation in plain Rust — numerically validated against the JAX
//! definitions in `python/compile/model.py` (forward, A8 fake-quant, NLL,
//! and the linear-weight gradients, incl. a finite-difference check below).
//!
//! Fidelity over speed: this is the reference semantics for the serving
//! path; the PJRT backend (`--features xla`) replaces it for performance.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::Matrix;
use crate::util::{parallel, Json};

use super::backend::{Backend, Buffer, ExecutableImpl, Literal};
use super::kernels::{self, dot, matmul_nt, matmul_tn};
use super::kvcache::{KvCache, LayerView};

/// sqrt(2/pi) for the tanh GELU approximation (jax.nn.gelu default).
const GELU_C: f32 = 0.797_884_56;

/// The pure-Rust interpreter backend (see module docs).
pub struct SimBackend;

impl Backend for SimBackend {
    fn platform_name(&self) -> String {
        "sim-cpu".into()
    }

    /// The interpreter reads (B, S) from the token literal itself
    /// (`split_model_inputs`), so any leading batch dim works — partial
    /// serving batches only pay for the rows they carry.
    fn supports_dynamic_batch(&self) -> bool {
        true
    }

    /// The interpreter's `fwd` graphs decode incrementally against a
    /// per-request [`KvCache`] (see [`forward_incremental`]).
    fn supports_incremental_decode(&self) -> bool {
        true
    }

    fn upload(&self, lit: &Literal) -> Result<Buffer> {
        Ok(Buffer::Host(lit.clone()))
    }

    fn load(&self, path: &Path) -> Result<Box<dyn ExecutableImpl>> {
        anyhow::ensure!(
            path.exists(),
            "no graph artifact at {} — run `make artifacts` first",
            path.display()
        );
        let stem = graph_stem(path)?;
        let dir = path.parent().unwrap_or_else(|| Path::new("."));
        let graph = match stem.as_str() {
            "nll_fp" => SimGraph::Model { spec: ModelSpec::load(dir)?, kind: ModelKind::NllFp },
            "nll_a8" => SimGraph::Model { spec: ModelSpec::load(dir)?, kind: ModelKind::NllA8 },
            "fwd_fp" => SimGraph::Model { spec: ModelSpec::load(dir)?, kind: ModelKind::FwdFp },
            "grad" => SimGraph::Model { spec: ModelSpec::load(dir)?, kind: ModelKind::Grad },
            "halo_matmul" => SimGraph::HaloMatmul,
            "spmv" => SimGraph::Spmv { out_dim: spmv_out_dim(dir)? },
            other => bail!(
                "sim backend cannot interpret graph `{other}` ({}); \
                 build with --features xla for arbitrary HLO",
                path.display()
            ),
        };
        Ok(Box::new(graph))
    }
}

/// `models/tiny/nll_fp.hlo.txt` → `nll_fp`.
fn graph_stem(path: &Path) -> Result<String> {
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("bad artifact path {}", path.display()))?;
    Ok(name
        .strip_suffix(".hlo.txt")
        .unwrap_or(name.strip_suffix(".txt").unwrap_or(name))
        .to_string())
}

/// Output width of the spmv kernel, from the sibling `kernels.json`.
fn spmv_out_dim(dir: &Path) -> Result<usize> {
    let meta = Json::parse(
        &std::fs::read_to_string(dir.join("kernels.json"))
            .with_context(|| format!("sim backend needs {}/kernels.json", dir.display()))?,
    )?;
    meta.path(&["spmv", "n"])?.as_usize()
}

/// Which lowered model graph is being interpreted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// `(params..., tokens (B, S+1)) -> (mean NLL,)` — f32 activations.
    NllFp,
    /// Same, with per-token A8 fake-quantized activations at every GEMM.
    NllA8,
    /// `(params..., tokens (B, S)) -> (logits (B, S, V),)`.
    FwdFp,
    /// `(params..., tokens (B, S+1)) -> (loss, dW per linear weight)`.
    Grad,
}

enum SimGraph {
    Model { spec: ModelSpec, kind: ModelKind },
    HaloMatmul,
    Spmv { out_dim: usize },
}

impl ExecutableImpl for SimGraph {
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        match self {
            SimGraph::Model { spec, kind } => run_model_graph(spec, *kind, inputs),
            SimGraph::HaloMatmul => run_halo_matmul(inputs),
            SimGraph::Spmv { out_dim } => run_spmv(*out_dim, inputs),
        }
    }

    fn run_buffers(&self, inputs: &[&Buffer]) -> Result<Vec<Literal>> {
        let lits: Vec<&Literal> = inputs
            .iter()
            .map(|b| b.as_host())
            .collect::<Result<_>>()?;
        self.run(&lits)
    }

    /// Only the logits-producing `fwd` graph decodes incrementally (the
    /// NLL/grad graphs are training-shaped; the standalone kernels have
    /// no sequence axis at all).
    fn supports_incremental_decode(&self) -> bool {
        matches!(self, SimGraph::Model { kind: ModelKind::FwdFp, .. })
    }

    fn run_decode_step(
        &self,
        params: &[&Buffer],
        tokens: &[i32],
        pos0: usize,
        cache: &mut KvCache,
    ) -> Result<Literal> {
        let SimGraph::Model { spec, kind: ModelKind::FwdFp } = self else {
            bail!("incremental decode is only supported on fwd graphs");
        };
        let lits: Vec<&Literal> = params
            .iter()
            .map(|b| b.as_host())
            .collect::<Result<_>>()?;
        let p = Params::bind(spec, &lits)?;
        let logits = forward_incremental(spec, &p, tokens, pos0, cache, false)?;
        Literal::f32(&logits.data, &[logits.rows, logits.cols])
    }
}

// ---------------------------------------------------------------- model spec

/// The transformer hyper-parameters + canonical parameter table, parsed from
/// the artifact `config.json` (the same contract `artifacts.rs` loads).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Vocabulary size (logit width).
    pub vocab: usize,
    /// Residual-stream width.
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Attention heads per layer (`d_model % n_heads == 0`).
    pub n_heads: usize,
    /// MLP hidden width.
    pub d_ff: usize,
    /// Context window (positional-embedding rows).
    pub seq_len: usize,
    /// Parameter names in canonical (lowered-graph input) order.
    pub names: Vec<String>,
    /// Parameter shapes, parallel to `names`.
    pub shapes: Vec<Vec<usize>>,
    /// Which parameters are linear weights, parallel to `names`.
    pub linear: Vec<bool>,
}

impl ModelSpec {
    /// Parse the spec from an artifact directory's `config.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let meta = Json::parse(
            &std::fs::read_to_string(dir.join("config.json"))
                .with_context(|| format!("sim backend needs {}/config.json", dir.display()))?,
        )?;
        Self::from_json(&meta)
    }

    /// Parse the spec from an already-loaded `config.json` object.
    pub fn from_json(meta: &Json) -> Result<Self> {
        let cfg = meta.req("config")?;
        let mut names = Vec::new();
        let mut shapes = Vec::new();
        let mut linear = Vec::new();
        for e in meta.req("params")?.as_arr()? {
            names.push(e.req("name")?.as_str()?.to_string());
            shapes.push(
                e.req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|x| x.as_usize())
                    .collect::<Result<_>>()?,
            );
            linear.push(e.req("linear")?.as_bool()?);
        }
        let spec = Self {
            vocab: cfg.req("vocab")?.as_usize()?,
            d_model: cfg.req("d_model")?.as_usize()?,
            n_layers: cfg.req("n_layers")?.as_usize()?,
            n_heads: cfg.req("n_heads")?.as_usize()?,
            d_ff: cfg.req("d_ff")?.as_usize()?,
            seq_len: cfg.req("seq_len")?.as_usize()?,
            names,
            shapes,
            linear,
        };
        anyhow::ensure!(
            spec.n_heads > 0 && spec.d_model % spec.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            spec.d_model,
            spec.n_heads
        );
        Ok(spec)
    }

    /// Per-head width (`d_model / n_heads`).
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Synthetic spec with the canonical parameter layout of
    /// `model.py::param_specs` — the single source of the
    /// name/shape/linear table for artifact-free tests and benches
    /// (`tests/qexec.rs`, `benches/l4_quant_exec.rs`, the in-crate sim
    /// tests), so test oracles and bench baselines always exercise the
    /// same model contract.
    pub fn synthetic(
        vocab: usize,
        d_model: usize,
        n_layers: usize,
        n_heads: usize,
        d_ff: usize,
        seq_len: usize,
    ) -> Self {
        let mut names = Vec::new();
        let mut shapes = Vec::new();
        let mut linear = Vec::new();
        let mut push = |nm: String, sh: Vec<usize>, lin: bool| {
            names.push(nm);
            shapes.push(sh);
            linear.push(lin);
        };
        push("embed".into(), vec![vocab, d_model], false);
        push("pos_embed".into(), vec![seq_len, d_model], false);
        for l in 0..n_layers {
            push(format!("layer{l}.ln1.scale"), vec![d_model], false);
            push(format!("layer{l}.ln1.bias"), vec![d_model], false);
            push(format!("layer{l}.attn.wq"), vec![d_model, d_model], true);
            push(format!("layer{l}.attn.wk"), vec![d_model, d_model], true);
            push(format!("layer{l}.attn.wv"), vec![d_model, d_model], true);
            push(format!("layer{l}.attn.wo"), vec![d_model, d_model], true);
            push(format!("layer{l}.ln2.scale"), vec![d_model], false);
            push(format!("layer{l}.ln2.bias"), vec![d_model], false);
            push(format!("layer{l}.mlp.w1"), vec![d_model, d_ff], true);
            push(format!("layer{l}.mlp.b1"), vec![d_ff], false);
            push(format!("layer{l}.mlp.w2"), vec![d_ff, d_model], true);
            push(format!("layer{l}.mlp.b2"), vec![d_model], false);
        }
        push("ln_f.scale".into(), vec![d_model], false);
        push("ln_f.bias".into(), vec![d_model], false);
        push("head".into(), vec![d_model, vocab], true);
        Self {
            vocab,
            d_model,
            n_layers,
            n_heads,
            d_ff,
            seq_len,
            names,
            shapes,
            linear,
        }
    }
}

/// Named parameter access for the shared forward pass (full-prefix
/// [`forward`] internals and incremental [`forward_incremental`] alike).
///
/// Three implementations exist: `Params` (positional literals with dense
/// f32 linear weights — the lowered-graph contract), [`DenseParams`] (an
/// owned dense store for artifact-free tests and benches), and the packed
/// quantized store in [`super::qkernels`], whose `linmul` runs the
/// integer W4A8 tile kernels (i8 panels × i8 activations, i32
/// accumulation, per-tile rescale) + fused SpMV instead of a dense
/// matmul.
pub trait ParamSource {
    /// Flat data of a parameter by name (embeddings, norm scales, biases).
    fn vec1(&self, name: &str) -> Result<&[f32]>;
    /// Dense 2-D parameter by name (backward pass; dense linear weights).
    fn mat(&self, name: &str) -> Result<Matrix>;
    /// `x @ W[name]` for a linear weight. The default densifies; packed
    /// sources override it to execute natively on the quantized form.
    fn linmul(&self, x: &Matrix, name: &str) -> Result<Matrix> {
        Ok(kernels::matmul(x, &self.mat(name)?))
    }
}

/// Owned dense parameter store implementing [`ParamSource`]: drives the
/// shared interpreter (full-prefix or incremental) without artifact files
/// or positional literals. Used by the differential decode suites
/// (`tests/decode_equiv.rs`) and `benches/l5_decode.rs` as the dense
/// reference path.
pub struct DenseParams {
    map: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl DenseParams {
    /// Build from `(name, shape, data)` triples; every parameter of
    /// `spec` must appear exactly once with its canonical shape.
    pub fn from_params<'a>(
        spec: &ModelSpec,
        params: impl IntoIterator<Item = (&'a str, &'a [usize], &'a [f32])>,
    ) -> Result<Self> {
        let mut map = BTreeMap::new();
        for (name, shape, data) in params {
            let i = spec
                .names
                .iter()
                .position(|n| n == name)
                .with_context(|| format!("parameter {name} not in model spec"))?;
            anyhow::ensure!(
                shape == spec.shapes[i].as_slice(),
                "parameter {name}: shape {shape:?} != spec {:?}",
                spec.shapes[i]
            );
            anyhow::ensure!(
                data.len() == shape.iter().product::<usize>(),
                "parameter {name}: data length {} != shape {shape:?}",
                data.len()
            );
            let prev = map.insert(name.to_string(), (shape.to_vec(), data.to_vec()));
            anyhow::ensure!(prev.is_none(), "duplicate parameter {name}");
        }
        anyhow::ensure!(
            map.len() == spec.names.len(),
            "expected {} parameters, got {}",
            spec.names.len(),
            map.len()
        );
        Ok(Self { map })
    }

    fn get(&self, name: &str) -> Result<&(Vec<usize>, Vec<f32>)> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing parameter {name}"))
    }
}

impl ParamSource for DenseParams {
    fn vec1(&self, name: &str) -> Result<&[f32]> {
        Ok(&self.get(name)?.1)
    }

    fn mat(&self, name: &str) -> Result<Matrix> {
        let (shape, data) = self.get(name)?;
        anyhow::ensure!(shape.len() == 2, "parameter {name} is not 2-D: {shape:?}");
        Ok(Matrix::from_vec(shape[0], shape[1], data.clone()))
    }
}

/// Positional inputs mapped back to named parameters (canonical order).
struct Params<'a> {
    map: BTreeMap<&'a str, (&'a [usize], &'a [f32])>,
}

impl<'a> Params<'a> {
    fn bind(spec: &'a ModelSpec, inputs: &[&'a Literal]) -> Result<Self> {
        anyhow::ensure!(
            inputs.len() == spec.names.len(),
            "expected {} parameter inputs, got {}",
            spec.names.len(),
            inputs.len()
        );
        let mut map = BTreeMap::new();
        for (i, name) in spec.names.iter().enumerate() {
            let want: usize = spec.shapes[i].iter().product();
            let data = inputs[i]
                .as_f32()
                .with_context(|| format!("parameter {name} must be f32"))?;
            anyhow::ensure!(
                data.len() == want,
                "parameter {name}: numel {} != expected {want}",
                data.len()
            );
            map.insert(name.as_str(), (spec.shapes[i].as_slice(), data));
        }
        Ok(Self { map })
    }

    fn get(&self, name: &str) -> Result<(&'a [usize], &'a [f32])> {
        self.map
            .get(name)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("missing parameter {name}"))
    }
}

impl<'a> ParamSource for Params<'a> {
    fn vec1(&self, name: &str) -> Result<&[f32]> {
        let (_, data) = self.get(name)?;
        Ok(data)
    }

    fn mat(&self, name: &str) -> Result<Matrix> {
        let (shape, data) = self.get(name)?;
        anyhow::ensure!(shape.len() == 2, "parameter {name} is not 2-D: {shape:?}");
        Ok(Matrix::from_vec(shape[0], shape[1], data.to_vec()))
    }
}

// ------------------------------------------------------------- linear algebra
//
// All GEMM-shaped work goes through the blocked, thread-parallel kernels
// in `runtime::kernels` (`matmul`/`matmul_tn`/`matmul_nt`); the seed
// single-pass implementations survive as `kernels::naive` and are compared
// against in `tests/hotpaths.rs`.

fn add_into(a: &mut Matrix, b: &Matrix) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    for (x, &y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

fn gelu(x: f32) -> f32 {
    let u = GELU_C * (x + 0.044715 * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

fn gelu_grad(x: f32) -> f32 {
    let u = GELU_C * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let du = GELU_C * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// Per-token (row) symmetric A8 fake quantization — mirror of
/// `python/compile/kernels/ref.py::fake_quant_act`.
pub fn fake_quant_rows(x: &Matrix) -> Matrix {
    let mut out = x.clone();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        let amax = row.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let s = if amax == 0.0 { 1.0 } else { amax / 127.0 };
        for v in row.iter_mut() {
            // Ties-to-even matches jnp.round in ref.py::fake_quant_act.
            *v = (*v / s).round_ties_even().clamp(-128.0, 127.0) * s;
        }
    }
    out
}

/// Row-wise layer norm; returns (y, x̂, 1/σ per row) — the caches the
/// backward pass needs.
fn layernorm(x: &Matrix, scale: &[f32], bias: &[f32]) -> (Matrix, Matrix, Vec<f32>) {
    let d = x.cols;
    let mut y = Matrix::zeros(x.rows, d);
    let mut xhat = Matrix::zeros(x.rows, d);
    let mut istd = Vec::with_capacity(x.rows);
    for r in 0..x.rows {
        let row = x.row(r);
        let mu = row.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        let var = row
            .iter()
            .map(|&v| {
                let e = v as f64 - mu;
                e * e
            })
            .sum::<f64>()
            / d as f64;
        let is = 1.0 / (var + 1e-5).sqrt();
        istd.push(is as f32);
        for c in 0..d {
            let xh = ((row[c] as f64 - mu) * is) as f32;
            xhat.set(r, c, xh);
            y.set(r, c, xh * scale[c] + bias[c]);
        }
    }
    (y, xhat, istd)
}

/// dx for y = x̂·γ + β:  dx = (dx̂ − mean(dx̂) − x̂·mean(dx̂·x̂)) / σ.
fn layernorm_backward(dy: &Matrix, xhat: &Matrix, istd: &[f32], scale: &[f32]) -> Matrix {
    let d = dy.cols;
    let mut dx = Matrix::zeros(dy.rows, d);
    for r in 0..dy.rows {
        let mut m1 = 0.0f64;
        let mut m2 = 0.0f64;
        for c in 0..d {
            let dxh = (dy.get(r, c) * scale[c]) as f64;
            m1 += dxh;
            m2 += dxh * xhat.get(r, c) as f64;
        }
        m1 /= d as f64;
        m2 /= d as f64;
        for c in 0..d {
            let dxh = (dy.get(r, c) * scale[c]) as f64;
            let v = (dxh - m1 - xhat.get(r, c) as f64 * m2) * istd[r] as f64;
            dx.set(r, c, v as f32);
        }
    }
    dx
}

// ----------------------------------------------------------------- attention

/// Below this much per-call work (≈ MACs across all heads), attention runs
/// its (batch, head) tasks serially instead of spawning scoped threads.
const ATTN_PAR_MIN_WORK: usize = 1 << 15;

/// Multi-head causal attention over projected q/k/v (each (b·s, d)).
/// Returns the merged output and, per (batch, head), the softmax weights.
///
/// One task per (batch, head) pair, fanned out over the worker pool; each
/// task fills its own (s, s) softmax table and (s, hd) output slice, so
/// the merge below is a plain copy and results are thread-count-
/// independent.
fn attention(
    b: usize,
    s: usize,
    heads: usize,
    hd: usize,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
) -> (Matrix, Vec<Matrix>) {
    let d = heads * hd;
    let scale = 1.0 / (hd as f64).sqrt();
    let head_task = |t: usize| {
        let (bi, h) = (t / heads, t % heads);
        let c0 = h * hd;
        let mut att = Matrix::zeros(s, s);
        let mut ao_h = Matrix::zeros(s, hd);
        for qi in 0..s {
            let qrow = &q.row(bi * s + qi)[c0..c0 + hd];
            let mut logits = vec![0.0f32; qi + 1];
            let mut maxv = f32::NEG_INFINITY;
            for (ki, l) in logits.iter_mut().enumerate() {
                let krow = &k.row(bi * s + ki)[c0..c0 + hd];
                *l = (dot(qrow, krow) as f64 * scale) as f32;
                maxv = maxv.max(*l);
            }
            let mut denom = 0.0f64;
            for l in logits.iter_mut() {
                let e = ((*l - maxv) as f64).exp();
                *l = e as f32;
                denom += e;
            }
            for (ki, &e) in logits.iter().enumerate() {
                att.set(qi, ki, (e as f64 / denom) as f32);
            }
            for j in 0..hd {
                let mut acc = 0.0f32;
                for ki in 0..=qi {
                    acc += att.get(qi, ki) * v.row(bi * s + ki)[c0 + j];
                }
                ao_h.set(qi, j, acc);
            }
        }
        (att, ao_h)
    };
    // Unit-test-sized heads aren't worth a thread spawn per call.
    let per_head = if b * heads * s * s * hd >= ATTN_PAR_MIN_WORK {
        parallel::par_map(b * heads, &head_task)
    } else {
        (0..b * heads).map(head_task).collect()
    };

    let mut ao = Matrix::zeros(b * s, d);
    let mut atts = Vec::with_capacity(b * heads);
    for (t, (att, ao_h)) in per_head.into_iter().enumerate() {
        let (bi, h) = (t / heads, t % heads);
        let c0 = h * hd;
        for qi in 0..s {
            ao.row_mut(bi * s + qi)[c0..c0 + hd].copy_from_slice(ao_h.row(qi));
        }
        atts.push(att);
    }
    (ao, atts)
}

/// Backward through causal attention given the cached softmax weights.
/// Returns (dq, dk, dv), each (b·s, d). Parallel over (batch, head) like
/// the forward pass: each task accumulates into its own (s, hd) slices.
#[allow(clippy::too_many_arguments)]
fn attention_backward(
    b: usize,
    s: usize,
    heads: usize,
    hd: usize,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    atts: &[Matrix],
    dao: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    let d = heads * hd;
    let scale = (1.0 / (hd as f64).sqrt()) as f32;
    let head_task = |t: usize| {
        let (bi, h) = (t / heads, t % heads);
        let c0 = h * hd;
        let att = &atts[bi * heads + h];
        let mut dq_h = Matrix::zeros(s, hd);
        let mut dk_h = Matrix::zeros(s, hd);
        let mut dv_h = Matrix::zeros(s, hd);
        for qi in 0..s {
            let dorow = &dao.row(bi * s + qi)[c0..c0 + hd];
            // datt[ki] = ⟨dao_qi, v_ki⟩ over this head's slice.
            let mut datt = vec![0.0f32; qi + 1];
            for (ki, dl) in datt.iter_mut().enumerate() {
                let vrow = &v.row(bi * s + ki)[c0..c0 + hd];
                *dl = dot(dorow, vrow);
            }
            // Softmax backward: dz = att ⊙ (datt − Σ datt·att).
            let rowsum: f64 = datt
                .iter()
                .enumerate()
                .map(|(ki, &dl)| dl as f64 * att.get(qi, ki) as f64)
                .sum();
            for (ki, &dl) in datt.iter().enumerate() {
                let aw = att.get(qi, ki);
                let dz = aw * (dl - rowsum as f32);
                let qrow = &q.row(bi * s + qi)[c0..c0 + hd];
                let krow = &k.row(bi * s + ki)[c0..c0 + hd];
                let dqrow = dq_h.row_mut(qi);
                for j in 0..hd {
                    dqrow[j] += dz * krow[j] * scale;
                }
                let dkrow = dk_h.row_mut(ki);
                for j in 0..hd {
                    dkrow[j] += dz * qrow[j] * scale;
                }
                let dvrow = dv_h.row_mut(ki);
                for j in 0..hd {
                    dvrow[j] += aw * dorow[j];
                }
            }
        }
        (dq_h, dk_h, dv_h)
    };
    let per_head = if b * heads * s * s * hd >= ATTN_PAR_MIN_WORK {
        parallel::par_map(b * heads, &head_task)
    } else {
        (0..b * heads).map(head_task).collect()
    };

    let mut dq = Matrix::zeros(b * s, d);
    let mut dk = Matrix::zeros(b * s, d);
    let mut dv = Matrix::zeros(b * s, d);
    for (t, (dq_h, dk_h, dv_h)) in per_head.into_iter().enumerate() {
        let (bi, h) = (t / heads, t % heads);
        let c0 = h * hd;
        for r in 0..s {
            dq.row_mut(bi * s + r)[c0..c0 + hd].copy_from_slice(dq_h.row(r));
            dk.row_mut(bi * s + r)[c0..c0 + hd].copy_from_slice(dk_h.row(r));
            dv.row_mut(bi * s + r)[c0..c0 + hd].copy_from_slice(dv_h.row(r));
        }
    }
    (dq, dk, dv)
}

// ------------------------------------------------------------------- forward

pub(crate) struct LayerCache {
    xhat1: Matrix,
    istd1: Vec<f32>,
    /// GEMM input for q/k/v (fake-quantized under A8).
    a_in1: Matrix,
    q: Matrix,
    k: Matrix,
    v: Matrix,
    atts: Vec<Matrix>,
    a_ao: Matrix,
    xhat2: Matrix,
    istd2: Vec<f32>,
    a_hn2: Matrix,
    pre_act: Matrix,
    a_h1: Matrix,
}

pub(crate) struct FinalCache {
    xhat_f: Matrix,
    istd_f: Vec<f32>,
    a_xf: Matrix,
}

/// The shared forward pass (mirror of `model.py::_forward`), caching every
/// intermediate the backward pass needs. `tokens` is (b, s) row-major.
/// Every linear GEMM routes through [`ParamSource::linmul`], so the same
/// code serves dense literals and the packed quantized store.
pub(crate) fn forward(
    spec: &ModelSpec,
    p: &dyn ParamSource,
    tokens: &[i32],
    b: usize,
    s: usize,
    a8: bool,
) -> Result<(Matrix, Vec<LayerCache>, FinalCache)> {
    let d = spec.d_model;
    anyhow::ensure!(s >= 1 && tokens.len() == b * s, "bad token batch shape");
    anyhow::ensure!(
        s <= spec.seq_len,
        "sequence length {s} exceeds the model's {}",
        spec.seq_len
    );
    let act = |m: &Matrix| if a8 { fake_quant_rows(m) } else { m.clone() };

    // Embedding + positional embedding.
    let embed = p.vec1("embed")?;
    let pos = p.vec1("pos_embed")?;
    let mut x = Matrix::zeros(b * s, d);
    for bi in 0..b {
        for si in 0..s {
            let t = tokens[bi * s + si];
            anyhow::ensure!(
                t >= 0 && (t as usize) < spec.vocab,
                "token {t} out of vocab range {}",
                spec.vocab
            );
            let erow = &embed[t as usize * d..(t as usize + 1) * d];
            let prow = &pos[si * d..(si + 1) * d];
            let xrow = x.row_mut(bi * s + si);
            for c in 0..d {
                xrow[c] = erow[c] + prow[c];
            }
        }
    }

    let mut caches = Vec::with_capacity(spec.n_layers);
    for i in 0..spec.n_layers {
        let pre = format!("layer{i}.");
        let (hn1, xhat1, istd1) = layernorm(
            &x,
            p.vec1(&format!("{pre}ln1.scale"))?,
            p.vec1(&format!("{pre}ln1.bias"))?,
        );
        let a_in1 = act(&hn1);
        let q = p.linmul(&a_in1, &format!("{pre}attn.wq"))?;
        let k = p.linmul(&a_in1, &format!("{pre}attn.wk"))?;
        let v = p.linmul(&a_in1, &format!("{pre}attn.wv"))?;
        let (ao, atts) = attention(b, s, spec.n_heads, spec.head_dim(), &q, &k, &v);
        let a_ao = act(&ao);
        add_into(&mut x, &p.linmul(&a_ao, &format!("{pre}attn.wo"))?);

        let (hn2, xhat2, istd2) = layernorm(
            &x,
            p.vec1(&format!("{pre}ln2.scale"))?,
            p.vec1(&format!("{pre}ln2.bias"))?,
        );
        let a_hn2 = act(&hn2);
        let b1 = p.vec1(&format!("{pre}mlp.b1"))?;
        let mut pre_act = p.linmul(&a_hn2, &format!("{pre}mlp.w1"))?;
        for r in 0..pre_act.rows {
            let row = pre_act.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                *v += b1[c];
            }
        }
        let mut h1 = pre_act.clone();
        for v in h1.data.iter_mut() {
            *v = gelu(*v);
        }
        let a_h1 = act(&h1);
        let b2 = p.vec1(&format!("{pre}mlp.b2"))?;
        let mut mlp_out = p.linmul(&a_h1, &format!("{pre}mlp.w2"))?;
        for r in 0..mlp_out.rows {
            let row = mlp_out.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                *v += b2[c];
            }
        }
        add_into(&mut x, &mlp_out);

        caches.push(LayerCache {
            xhat1,
            istd1,
            a_in1,
            q,
            k,
            v,
            atts,
            a_ao,
            xhat2,
            istd2,
            a_hn2,
            pre_act,
            a_h1,
        });
    }

    let (xf, xhat_f, istd_f) =
        layernorm(&x, p.vec1("ln_f.scale")?, p.vec1("ln_f.bias")?);
    let a_xf = act(&xf);
    let logits = p.linmul(&a_xf, "head")?;
    Ok((logits, caches, FinalCache { xhat_f, istd_f, a_xf }))
}

/// Full-prefix logits for a `(b, s)` token batch through any parameter
/// source — the recompute oracle the KV-cached incremental path is pinned
/// against (`tests/decode_equiv.rs`).
pub fn forward_logits(
    spec: &ModelSpec,
    p: &dyn ParamSource,
    tokens: &[i32],
    b: usize,
    s: usize,
) -> Result<Matrix> {
    crate::util::failpoint::check(crate::util::failpoint::sites::SIM_RUN)?;
    let (logits, _, _) = forward(spec, p, tokens, b, s, false)?;
    Ok(logits)
}

// -------------------------------------------------------- incremental decode

/// KV-cached incremental forward pass: evaluates only `tokens` (the
/// window suffix at absolute positions `pos0..pos0 + tokens.len()`),
/// appending each layer's new K/V rows to `cache` and attending every new
/// query against the cached prefix. With `pos0 = 0` and an empty cache
/// this *is* the prefill pass.
///
/// Bit-identical to running [`forward_logits`] over the whole window and
/// reading the same rows (pinned by `tests/decode_equiv.rs`): every
/// per-position computation of the full pass is row-local — embedding,
/// layernorm, the blocked/packed GEMMs (ascending-`k` summation order,
/// independent of the row count), GELU, A8 fake-quant — except causal
/// attention, which `attention_cached` replays with the exact summation
/// order of the full pass's attention kernel. Works for every
/// [`ParamSource`], so the packed `qmatmul` path gets incremental decode
/// for free.
///
/// `cache` must hold exactly `pos0` committed positions (consistent
/// across layers) and `pos0 + tokens.len()` must stay within the model's
/// context window. Positional embeddings ring over the context window:
/// a new token embeds at `cache.positions_seen() % seq_len`, which
/// equals its window row until the first slide and keeps advancing
/// (mod `seq_len`) afterwards, so a context slide *re-bases* the cache
/// (`KvCache::pop_front`) instead of clearing it — decode past the cap
/// is streaming attention over the retained rows, pinned
/// block-size-invariant by `tests/decode_equiv.rs`. Returns the
/// `(tokens.len(), vocab)` logits rows for the new positions. On error
/// the cache may hold a partial append; clear it before reuse (the
/// consistency check here refuses stale caches).
pub fn forward_incremental(
    spec: &ModelSpec,
    p: &dyn ParamSource,
    tokens: &[i32],
    pos0: usize,
    cache: &mut KvCache,
    a8: bool,
) -> Result<Matrix> {
    crate::util::failpoint::check(crate::util::failpoint::sites::SIM_RUN)?;
    let d = spec.d_model;
    let n = tokens.len();
    anyhow::ensure!(n >= 1, "incremental step needs at least one token");
    anyhow::ensure!(
        pos0 + n <= spec.seq_len,
        "window end {} exceeds the model's context {}",
        pos0 + n,
        spec.seq_len
    );
    anyhow::ensure!(
        cache.n_layers() == spec.n_layers && cache.d_model() == d,
        "KV cache shape ({} layers, d {}) does not match the model ({}, {})",
        cache.n_layers(),
        cache.d_model(),
        spec.n_layers,
        d
    );
    anyhow::ensure!(
        cache.len() == pos0 && cache.is_consistent(),
        "KV cache holds {} committed positions (consistent: {}), expected {pos0} — \
         clear() and re-prefill after a slide or a failed step",
        cache.len(),
        cache.is_consistent()
    );
    let act = |m: &Matrix| if a8 { fake_quant_rows(m) } else { m.clone() };

    // Embedding + positional embedding for the new rows only.
    let embed = p.vec1("embed")?;
    let pos = p.vec1("pos_embed")?;
    let mut x = Matrix::zeros(n, d);
    for (i, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(
            t >= 0 && (t as usize) < spec.vocab,
            "token {t} out of vocab range {}",
            spec.vocab
        );
        let erow = &embed[t as usize * d..(t as usize + 1) * d];
        // Ring position: monotone committed-position count mod context.
        // Equal to `pos0 + i` until the first slide (positions_seen ==
        // len == pos0 for never-slid caches), so pre-slide chains stay
        // bit-identical to full-window recompute.
        let ring = (cache.positions_seen() + i) % spec.seq_len;
        let prow = &pos[ring * d..(ring + 1) * d];
        let xrow = x.row_mut(i);
        for c in 0..d {
            xrow[c] = erow[c] + prow[c];
        }
    }

    for l in 0..spec.n_layers {
        let pre = format!("layer{l}.");
        let (hn1, _, _) = layernorm(
            &x,
            p.vec1(&format!("{pre}ln1.scale"))?,
            p.vec1(&format!("{pre}ln1.bias"))?,
        );
        let a_in1 = act(&hn1);
        let q = p.linmul(&a_in1, &format!("{pre}attn.wq"))?;
        let k = p.linmul(&a_in1, &format!("{pre}attn.wk"))?;
        let v = p.linmul(&a_in1, &format!("{pre}attn.wv"))?;
        cache.append(l, &k, &v)?;
        let ao = attention_cached(pos0, n, spec.n_heads, spec.head_dim(), &q, cache.layer(l));
        let a_ao = act(&ao);
        add_into(&mut x, &p.linmul(&a_ao, &format!("{pre}attn.wo"))?);

        let (hn2, _, _) = layernorm(
            &x,
            p.vec1(&format!("{pre}ln2.scale"))?,
            p.vec1(&format!("{pre}ln2.bias"))?,
        );
        let a_hn2 = act(&hn2);
        let b1 = p.vec1(&format!("{pre}mlp.b1"))?;
        let mut h1 = p.linmul(&a_hn2, &format!("{pre}mlp.w1"))?;
        for r in 0..h1.rows {
            let row = h1.row_mut(r);
            for (c, hv) in row.iter_mut().enumerate() {
                *hv = gelu(*hv + b1[c]);
            }
        }
        let a_h1 = act(&h1);
        let b2 = p.vec1(&format!("{pre}mlp.b2"))?;
        let mut mlp_out = p.linmul(&a_h1, &format!("{pre}mlp.w2"))?;
        for r in 0..mlp_out.rows {
            let row = mlp_out.row_mut(r);
            for (c, mv) in row.iter_mut().enumerate() {
                *mv += b2[c];
            }
        }
        add_into(&mut x, &mlp_out);
    }
    cache.commit(tokens)?;

    let (xf, _, _) = layernorm(&x, p.vec1("ln_f.scale")?, p.vec1("ln_f.bias")?);
    let a_xf = act(&xf);
    p.linmul(&a_xf, "head")
}

/// Causal attention for `n` new query rows at absolute positions
/// `pos0..pos0 + n`, against a layer's K/V cache (which already holds the
/// new rows). Mirrors [`attention`]'s numerics exactly — f64-scaled f32
/// logits, max-subtracted exp with an f64 softmax denominator, f32 weight
/// rounding, keys ascending — so cached decode stays bit-identical to the
/// full-prefix pass. Reads rows through the paged cache's [`LayerView`];
/// the summation order is unchanged from the contiguous layout.
fn attention_cached(
    pos0: usize,
    n: usize,
    heads: usize,
    hd: usize,
    q: &Matrix,
    kv: LayerView<'_>,
) -> Matrix {
    let d = heads * hd;
    let scale = 1.0 / (hd as f64).sqrt();
    let mut ao = Matrix::zeros(n, d);
    let mut weights: Vec<f32> = Vec::new();
    for h in 0..heads {
        let c0 = h * hd;
        for qi in 0..n {
            let span = pos0 + qi + 1; // keys 0..=pos0+qi
            let qrow = &q.row(qi)[c0..c0 + hd];
            weights.clear();
            weights.resize(span, 0.0);
            let mut maxv = f32::NEG_INFINITY;
            for (ki, l) in weights.iter_mut().enumerate() {
                let krow = &kv.k_row(ki)[c0..c0 + hd];
                *l = (dot(qrow, krow) as f64 * scale) as f32;
                maxv = maxv.max(*l);
            }
            let mut denom = 0.0f64;
            for l in weights.iter_mut() {
                let e = ((*l - maxv) as f64).exp();
                *l = e as f32;
                denom += e;
            }
            for l in weights.iter_mut() {
                *l = (*l as f64 / denom) as f32;
            }
            let orow = &mut ao.row_mut(qi)[c0..c0 + hd];
            for (j, ov) in orow.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (ki, &aw) in weights.iter().enumerate() {
                    acc += aw * kv.v_row(ki)[c0 + j];
                }
                *ov = acc;
            }
        }
    }
    ao
}

/// Mean next-token NLL and ∂loss/∂logits = (softmax − onehot)/n.
fn nll_and_dlogits(logits: &Matrix, targets: &[i32]) -> Result<(f32, Matrix)> {
    let (n, v) = (logits.rows, logits.cols);
    anyhow::ensure!(targets.len() == n, "target length mismatch");
    let mut d = Matrix::zeros(n, v);
    let mut total = 0.0f64;
    for r in 0..n {
        let row = logits.row(r);
        let t = targets[r];
        anyhow::ensure!(t >= 0 && (t as usize) < v, "target {t} out of range {v}");
        let maxv = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
        let mut denom = 0.0f64;
        for &x in row {
            denom += ((x - maxv) as f64).exp();
        }
        total += maxv as f64 + denom.ln() - row[t as usize] as f64;
        let drow = d.row_mut(r);
        for c in 0..v {
            let mut g = ((row[c] - maxv) as f64).exp() / denom;
            if c == t as usize {
                g -= 1.0;
            }
            drow[c] = (g / n as f64) as f32;
        }
    }
    Ok(((total / n as f64) as f32, d))
}

/// Mean NLL over a (b, s+1) token batch — the `nll_fp` / `nll_a8` graphs.
pub fn model_loss(spec: &ModelSpec, inputs: &[&Literal], a8: bool) -> Result<f32> {
    let (p, tokens, b, t) = split_model_inputs(spec, inputs)?;
    anyhow::ensure!(t >= 2, "NLL graphs need (b, s+1) tokens with s >= 1");
    let s = t - 1;
    let (inp, tgt) = split_next_token(tokens, b, s);
    let (logits, _, _) = forward(spec, &p, &inp, b, s, a8)?;
    let (loss, _) = nll_and_dlogits(&logits, &tgt)?;
    Ok(loss)
}

/// `(loss, dW per linear weight in canonical order)` — the `grad` graph.
/// Backward mirrors the JAX autodiff of `model.py::loss_fn` (validated by
/// the finite-difference test below).
pub fn model_grads(
    spec: &ModelSpec,
    inputs: &[&Literal],
) -> Result<(f32, Vec<(String, Matrix)>)> {
    let (p, tokens, b, t) = split_model_inputs(spec, inputs)?;
    anyhow::ensure!(t >= 2, "grad graph needs (b, s+1) tokens with s >= 1");
    let s = t - 1;
    let (inp, tgt) = split_next_token(tokens, b, s);
    let (logits, caches, fin) = forward(spec, &p, &inp, b, s, false)?;
    let (loss, dlogits) = nll_and_dlogits(&logits, &tgt)?;

    let mut grads: BTreeMap<String, Matrix> = BTreeMap::new();
    grads.insert("head".into(), matmul_tn(&fin.a_xf, &dlogits));
    let dxf = matmul_nt(&dlogits, &p.mat("head")?);
    let mut dx = layernorm_backward(&dxf, &fin.xhat_f, &fin.istd_f, p.vec1("ln_f.scale")?);

    for i in (0..spec.n_layers).rev() {
        let pre = format!("layer{i}.");
        let c = &caches[i];
        // MLP: x = x_mid + gelu(hn2 @ w1 + b1) @ w2 + b2
        grads.insert(format!("{pre}mlp.w2"), matmul_tn(&c.a_h1, &dx));
        let dh1 = matmul_nt(&dx, &p.mat(&format!("{pre}mlp.w2"))?);
        let mut dpre = dh1;
        for (v, &x) in dpre.data.iter_mut().zip(&c.pre_act.data) {
            *v *= gelu_grad(x);
        }
        grads.insert(format!("{pre}mlp.w1"), matmul_tn(&c.a_hn2, &dpre));
        let dhn2 = matmul_nt(&dpre, &p.mat(&format!("{pre}mlp.w1"))?);
        add_into(
            &mut dx,
            &layernorm_backward(&dhn2, &c.xhat2, &c.istd2, p.vec1(&format!("{pre}ln2.scale"))?),
        );

        // Attention: x_mid = x_in + attn(hn1) @ wo
        grads.insert(format!("{pre}attn.wo"), matmul_tn(&c.a_ao, &dx));
        let dao = matmul_nt(&dx, &p.mat(&format!("{pre}attn.wo"))?);
        let (dq, dk, dv) = attention_backward(
            b,
            s,
            spec.n_heads,
            spec.head_dim(),
            &c.q,
            &c.k,
            &c.v,
            &c.atts,
            &dao,
        );
        grads.insert(format!("{pre}attn.wq"), matmul_tn(&c.a_in1, &dq));
        grads.insert(format!("{pre}attn.wk"), matmul_tn(&c.a_in1, &dk));
        grads.insert(format!("{pre}attn.wv"), matmul_tn(&c.a_in1, &dv));
        let mut dhn1 = matmul_nt(&dq, &p.mat(&format!("{pre}attn.wq"))?);
        add_into(&mut dhn1, &matmul_nt(&dk, &p.mat(&format!("{pre}attn.wk"))?));
        add_into(&mut dhn1, &matmul_nt(&dv, &p.mat(&format!("{pre}attn.wv"))?));
        add_into(
            &mut dx,
            &layernorm_backward(&dhn1, &c.xhat1, &c.istd1, p.vec1(&format!("{pre}ln1.scale"))?),
        );
    }

    // Canonical linear order, exactly like the lowered grad graph's outputs.
    let mut out = Vec::new();
    for (i, name) in spec.names.iter().enumerate() {
        if spec.linear[i] {
            let g = grads
                .remove(name)
                .ok_or_else(|| anyhow::anyhow!("missing gradient for {name}"))?;
            out.push((name.clone(), g));
        }
    }
    Ok((loss, out))
}

/// Logits for a (b, s) token batch — the `fwd_fp` graph.
pub fn model_forward(spec: &ModelSpec, inputs: &[&Literal]) -> Result<(Matrix, usize, usize)> {
    let (p, tokens, b, s) = split_model_inputs(spec, inputs)?;
    let (logits, _, _) = forward(spec, &p, &tokens, b, s, false)?;
    Ok((logits, b, s))
}

fn split_model_inputs<'a>(
    spec: &'a ModelSpec,
    inputs: &[&'a Literal],
) -> Result<(Params<'a>, Vec<i32>, usize, usize)> {
    anyhow::ensure!(
        inputs.len() == spec.names.len() + 1,
        "expected {} inputs (params + tokens), got {}",
        spec.names.len() + 1,
        inputs.len()
    );
    let p = Params::bind(spec, &inputs[..spec.names.len()])?;
    let tok = inputs[spec.names.len()];
    anyhow::ensure!(
        tok.dims().len() == 2,
        "token batch must be 2-D, got dims {:?}",
        tok.dims()
    );
    let (b, t) = (tok.dims()[0], tok.dims()[1]);
    Ok((p, tok.as_i32()?.to_vec(), b, t))
}

/// Split a (b, s+1) stream into inputs (b, s) and next-token targets (b·s).
fn split_next_token(tokens: Vec<i32>, b: usize, s: usize) -> (Vec<i32>, Vec<i32>) {
    let mut inp = Vec::with_capacity(b * s);
    let mut tgt = Vec::with_capacity(b * s);
    for bi in 0..b {
        let row = &tokens[bi * (s + 1)..(bi + 1) * (s + 1)];
        inp.extend_from_slice(&row[..s]);
        tgt.extend_from_slice(&row[1..]);
    }
    (inp, tgt)
}

fn run_model_graph(spec: &ModelSpec, kind: ModelKind, inputs: &[&Literal]) -> Result<Vec<Literal>> {
    match kind {
        ModelKind::NllFp => Ok(vec![Literal::scalar_f32(model_loss(spec, inputs, false)?)]),
        ModelKind::NllA8 => Ok(vec![Literal::scalar_f32(model_loss(spec, inputs, true)?)]),
        ModelKind::FwdFp => {
            let (logits, b, s) = model_forward(spec, inputs)?;
            Ok(vec![Literal::f32(&logits.data, &[b, s, spec.vocab])?])
        }
        ModelKind::Grad => {
            let (loss, grads) = model_grads(spec, inputs)?;
            let mut out = vec![Literal::scalar_f32(loss)];
            for (_, g) in grads {
                out.push(Literal::f32(&g.data, &[g.rows, g.cols])?);
            }
            Ok(out)
        }
    }
}

// ------------------------------------------------------------------- kernels

/// `y = x @ (codebook[idx] · per_tile_scale)` — mirror of
/// `python/compile/kernels/ref.py::halo_matmul`.
pub fn run_halo_matmul(inputs: &[&Literal]) -> Result<Vec<Literal>> {
    anyhow::ensure!(inputs.len() == 4, "halo_matmul takes (x, idx, codebook, scales)");
    let (x, idx, cb, sc) = (inputs[0], inputs[1], inputs[2], inputs[3]);
    anyhow::ensure!(x.dims().len() == 2 && idx.dims().len() == 2 && sc.dims().len() == 2);
    let (m, k) = (x.dims()[0], x.dims()[1]);
    let (ki, n) = (idx.dims()[0], idx.dims()[1]);
    let (kt, nt) = (sc.dims()[0], sc.dims()[1]);
    anyhow::ensure!(k == ki, "x/idx inner dims disagree: {k} vs {ki}");
    anyhow::ensure!(kt > 0 && k % kt == 0, "scales rows {kt} do not tile K={k}");
    let tile = k / kt;
    anyhow::ensure!(nt > 0 && n % nt == 0 && n / nt == tile, "non-square tiling");
    let (xv, iv, cv, sv) = (x.as_f32()?, idx.as_i8()?, cb.as_f32()?, sc.as_f32()?);

    let mut wd = Matrix::zeros(k, n);
    for r in 0..k {
        for c in 0..n {
            let i = iv[r * n + c];
            anyhow::ensure!(
                i >= 0 && (i as usize) < cv.len(),
                "codebook index {i} out of range {}",
                cv.len()
            );
            wd.set(r, c, cv[i as usize] * sv[(r / tile) * nt + c / tile]);
        }
    }
    let y = kernels::matmul(&Matrix::from_vec(m, k, xv.to_vec()), &wd);
    Ok(vec![Literal::f32(&y.data, &[m, n])?])
}

/// `y = x @ W_sparse` for (val, pos) hypersparse storage — mirror of
/// `python/compile/kernels/ref.py::spmv`.
pub fn run_spmv(out_dim: usize, inputs: &[&Literal]) -> Result<Vec<Literal>> {
    anyhow::ensure!(inputs.len() == 3, "spmv takes (val, pos, x)");
    let (val, pos, x) = (inputs[0], inputs[1], inputs[2]);
    anyhow::ensure!(x.dims().len() == 2, "spmv x must be 2-D");
    let (m, k) = (x.dims()[0], x.dims()[1]);
    let (vv, pv, xv) = (val.as_f32()?, pos.as_i32()?, x.as_f32()?);
    anyhow::ensure!(vv.len() == pv.len(), "val/pos length mismatch");

    let mut y = Matrix::zeros(m, out_dim);
    for (i, &v) in vv.iter().enumerate() {
        if v == 0.0 {
            continue;
        }
        let p = pv[i];
        anyhow::ensure!(p >= 0, "negative sparse position");
        let (r, c) = (p as usize / out_dim, p as usize % out_dim);
        anyhow::ensure!(r < k, "sparse position {p} outside ({k}, {out_dim})");
        for mi in 0..m {
            let add = xv[mi * k + r] * v;
            y.set(mi, c, y.get(mi, c) + add);
        }
    }
    Ok(vec![Literal::f32(&y.data, &[m, out_dim])?])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::sparse::SparseMatrix;
    use crate::util::Rng;

    fn tiny_spec() -> ModelSpec {
        // 1-layer toy config off the shared canonical layout
        // (ModelSpec::synthetic mirrors model.py::param_specs).
        ModelSpec::synthetic(11, 8, 1, 2, 16, 6)
    }

    fn tiny_inputs(spec: &ModelSpec, seed: u64) -> Vec<Literal> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut out = Vec::new();
        for (name, shape) in spec.names.iter().zip(&spec.shapes) {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if name.ends_with(".scale") {
                vec![1.0; n]
            } else if name.ends_with(".bias") || name.ends_with(".b1") || name.ends_with(".b2") {
                vec![0.0; n]
            } else {
                let std = 1.0 / (shape[0] as f32).sqrt();
                (0..n).map(|_| rng.gen_normal() as f32 * std).collect()
            };
            out.push(Literal::f32(&data, shape).unwrap());
        }
        // Token batch (2, s+1).
        let (b, s) = (2usize, spec.seq_len);
        let toks: Vec<i32> = (0..b * (s + 1))
            .map(|_| rng.gen_usize(spec.vocab) as i32)
            .collect();
        out.push(Literal::i32(&toks, &[b, s + 1]).unwrap());
        out
    }

    fn refs(v: &[Literal]) -> Vec<&Literal> {
        v.iter().collect()
    }

    #[test]
    fn loss_is_finite_and_deterministic() {
        let spec = tiny_spec();
        let inputs = tiny_inputs(&spec, 1);
        let a = model_loss(&spec, &refs(&inputs), false).unwrap();
        let b = model_loss(&spec, &refs(&inputs), false).unwrap();
        assert!(a.is_finite() && a > 0.0, "loss {a}");
        assert_eq!(a, b);
        // A near-untrained model sits near the uniform ceiling ln(vocab).
        let ceiling = (spec.vocab as f32).ln();
        assert!(a < 2.0 * ceiling, "loss {a} vs ceiling {ceiling}");
    }

    #[test]
    fn a8_close_to_fp_but_not_identical() {
        let spec = tiny_spec();
        let inputs = tiny_inputs(&spec, 2);
        let fp = model_loss(&spec, &refs(&inputs), false).unwrap();
        let a8 = model_loss(&spec, &refs(&inputs), true).unwrap();
        assert!((fp - a8).abs() / fp < 0.2, "fp {fp} vs a8 {a8}");
        assert_ne!(fp, a8);
    }

    #[test]
    fn grad_loss_matches_nll_graph() {
        let spec = tiny_spec();
        let inputs = tiny_inputs(&spec, 3);
        let nll = model_loss(&spec, &refs(&inputs), false).unwrap();
        let (loss, grads) = model_grads(&spec, &refs(&inputs)).unwrap();
        assert_eq!(nll, loss);
        assert_eq!(grads.len(), spec.linear.iter().filter(|&&l| l).count());
        for (name, g) in &grads {
            assert!(g.data.iter().any(|&x| x != 0.0), "{name} all-zero grad");
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        // Central differences on the largest-|grad| entry of every linear
        // weight — the correctness anchor for the whole backward pass.
        let spec = tiny_spec();
        let inputs = tiny_inputs(&spec, 4);
        let (_, grads) = model_grads(&spec, &refs(&inputs)).unwrap();
        let eps = 1e-2f32;
        for (name, g) in &grads {
            let (argmax, &gv) = g
                .data
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                .unwrap();
            let pidx = spec.names.iter().position(|n| n == name).unwrap();
            let loss_at = |delta: f32| {
                let mut shifted = inputs.clone();
                if let crate::runtime::backend::LiteralData::F32(v) = &mut shifted[pidx].data {
                    v[argmax] += delta;
                }
                model_loss(&spec, &refs(&shifted), false).unwrap()
            };
            let fd = (loss_at(eps) - loss_at(-eps)) / (2.0 * eps);
            let tol = 0.15 * fd.abs().max(gv.abs()) + 1e-4;
            assert!(
                (fd - gv).abs() <= tol,
                "{name}[{argmax}]: analytic {gv} vs fd {fd}"
            );
        }
    }

    #[test]
    fn fwd_logits_consistent_with_nll() {
        // Computing the NLL from the fwd graph's logits must equal the NLL
        // graph's own output.
        let spec = tiny_spec();
        let mut inputs = tiny_inputs(&spec, 5);
        let nll = model_loss(&spec, &refs(&inputs), false).unwrap();
        // Re-shape the token literal to the (b, s) fwd layout.
        let toks = inputs.pop().unwrap();
        let (b, t) = (toks.dims()[0], toks.dims()[1]);
        let (s, all) = (t - 1, toks.as_i32().unwrap().to_vec());
        let (inp, tgt) = split_next_token(all, b, s);
        inputs.push(Literal::i32(&inp, &[b, s]).unwrap());
        let (logits, lb, ls) = model_forward(&spec, &refs(&inputs)).unwrap();
        assert_eq!((lb, ls), (b, s));
        let (from_logits, _) = nll_and_dlogits(&logits, &tgt).unwrap();
        assert!((from_logits - nll).abs() < 1e-5, "{from_logits} vs {nll}");
    }

    #[test]
    fn halo_matmul_matches_dense_oracle() {
        let (m, k, n, tile) = (16usize, 32, 64, 16);
        let mut rng = Rng::seed_from_u64(10);
        let x: Vec<f32> = (0..m * k).map(|_| rng.gen_normal() as f32).collect();
        let idx: Vec<i8> = (0..k * n).map(|_| rng.gen_usize(16) as i8).collect();
        let cb: Vec<f32> = (0..16).map(|_| rng.gen_normal() as f32).collect();
        let sc: Vec<f32> = (0..(k / tile) * (n / tile))
            .map(|_| 0.5 + rng.gen_f64() as f32)
            .collect();
        let lits = vec![
            Literal::f32(&x, &[m, k]).unwrap(),
            Literal::i8(&idx, &[k, n]).unwrap(),
            Literal::f32(&cb, &[16]).unwrap(),
            Literal::f32(&sc, &[k / tile, n / tile]).unwrap(),
        ];
        let out = run_halo_matmul(&refs(&lits)).unwrap();
        let y: Vec<f32> = out[0].to_vec().unwrap();

        let mut wd = Matrix::zeros(k, n);
        for r in 0..k {
            for c in 0..n {
                let t = (r / tile) * (n / tile) + c / tile;
                wd.set(r, c, cb[idx[r * n + c] as usize] * sc[t]);
            }
        }
        let want = Matrix::from_vec(m, k, x).matmul(&wd);
        for (a, b) in y.iter().zip(&want.data) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn spmv_matches_sparse_oracle() {
        let (m, k, n) = (4usize, 24, 16);
        let mut rng = Rng::seed_from_u64(11);
        let mut used = std::collections::HashSet::new();
        let coords: Vec<(usize, usize, f32)> = (0..40)
            .filter_map(|_| {
                let r = rng.gen_usize(k);
                let c = rng.gen_usize(n);
                used.insert((r, c)).then(|| (r, c, rng.gen_normal() as f32))
            })
            .collect();
        let sp = SparseMatrix::from_coords(k, n, &coords);
        let x: Vec<f32> = (0..m * k).map(|_| rng.gen_normal() as f32).collect();
        let pos_i32: Vec<i32> = sp.pos.iter().map(|&p| p as i32).collect();
        let lits = vec![
            Literal::f32(&sp.val, &[sp.val.len()]).unwrap(),
            Literal::i32(&pos_i32, &[pos_i32.len()]).unwrap(),
            Literal::f32(&x, &[m, k]).unwrap(),
        ];
        let out = run_spmv(n, &refs(&lits)).unwrap();
        let y: Vec<f32> = out[0].to_vec().unwrap();
        let want = sp.spmv(&Matrix::from_vec(m, k, x));
        for (a, b) in y.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn fake_quant_properties() {
        let mut rng = Rng::seed_from_u64(12);
        let x = Matrix::random_normal(8, 32, 1.0, &mut rng);
        let q = fake_quant_rows(&x);
        for r in 0..x.rows {
            let amax = x.row(r).iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let s = amax / 127.0;
            for (a, b) in x.row(r).iter().zip(q.row(r)) {
                assert!((a - b).abs() <= s / 2.0 + 1e-6, "{a} vs {b}");
            }
        }
        // Zero rows stay exactly zero.
        let z = fake_quant_rows(&Matrix::zeros(2, 4));
        assert!(z.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn incremental_decode_matches_full_forward_bitexact() {
        // Prefill a 3-token prefix, then step the remaining positions one
        // token at a time: every logits row must be BIT-identical to the
        // full-prefix pass (the in-crate anchor behind the external
        // differential suite in tests/decode_equiv.rs).
        let spec = tiny_spec();
        let inputs = tiny_inputs(&spec, 7);
        let all = refs(&inputs);
        let p = Params::bind(&spec, &all[..spec.names.len()]).unwrap();
        let s = spec.seq_len;
        let mut rng = Rng::seed_from_u64(8);
        let toks: Vec<i32> = (0..s).map(|_| rng.gen_usize(spec.vocab) as i32).collect();
        let (full, _, _) = forward(&spec, &p, &toks, 1, s, false).unwrap();

        let mut cache = KvCache::new(spec.n_layers, spec.d_model);
        let pre = forward_incremental(&spec, &p, &toks[..3], 0, &mut cache, false).unwrap();
        assert_eq!((pre.rows, pre.cols), (3, spec.vocab));
        for r in 0..3 {
            assert_eq!(pre.row(r), full.row(r), "prefill row {r}");
        }
        for i in 3..s {
            let one =
                forward_incremental(&spec, &p, &toks[i..i + 1], i, &mut cache, false).unwrap();
            assert_eq!(one.rows, 1);
            assert_eq!(one.row(0), full.row(i), "incremental step at position {i}");
        }
        assert_eq!(cache.len(), s);
        assert!(cache.is_consistent());
    }

    #[test]
    fn incremental_decode_validates_cache_and_window() {
        let spec = tiny_spec();
        let inputs = tiny_inputs(&spec, 9);
        let all = refs(&inputs);
        let p = Params::bind(&spec, &all[..spec.names.len()]).unwrap();
        let mut cache = KvCache::new(spec.n_layers, spec.d_model);
        // pos0 must equal the committed cache length.
        assert!(forward_incremental(&spec, &p, &[1], 2, &mut cache, false).is_err());
        // The window end must stay inside the model context.
        let long: Vec<i32> = vec![1; spec.seq_len + 1];
        assert!(forward_incremental(&spec, &p, &long, 0, &mut cache, false).is_err());
        // Empty steps are rejected.
        assert!(forward_incremental(&spec, &p, &[], 0, &mut cache, false).is_err());
        // A mismatched cache shape is rejected.
        let mut wrong = KvCache::new(spec.n_layers + 1, spec.d_model);
        assert!(forward_incremental(&spec, &p, &[1], 0, &mut wrong, false).is_err());
        // And the happy path still works afterwards.
        assert!(forward_incremental(&spec, &p, &[1, 2], 0, &mut cache, false).is_ok());
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn dense_params_matches_literal_params() {
        // DenseParams (owned store) and Params (positional literals) are
        // the same dense semantics: identical logits, full and cached.
        let spec = tiny_spec();
        let inputs = tiny_inputs(&spec, 10);
        let all = refs(&inputs);
        let p = Params::bind(&spec, &all[..spec.names.len()]).unwrap();
        let triples: Vec<(String, Vec<usize>, Vec<f32>)> = spec
            .names
            .iter()
            .zip(&spec.shapes)
            .enumerate()
            .map(|(i, (n, sh))| (n.clone(), sh.clone(), inputs[i].as_f32().unwrap().to_vec()))
            .collect();
        let dp = DenseParams::from_params(
            &spec,
            triples.iter().map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice())),
        )
        .unwrap();
        let toks: Vec<i32> = (0..spec.seq_len as i32).map(|t| t % spec.vocab as i32).collect();
        let a = forward_logits(&spec, &p, &toks, 1, spec.seq_len).unwrap();
        let b = forward_logits(&spec, &dp, &toks, 1, spec.seq_len).unwrap();
        assert_eq!(a.data, b.data);
        // Missing / duplicate parameters are rejected at construction.
        assert!(DenseParams::from_params(
            &spec,
            triples.iter().take(2).map(|(n, s, d)| (n.as_str(), s.as_slice(), d.as_slice())),
        )
        .is_err());
    }

    /// Write a `config.json` for `spec` into a fresh temp dir (the
    /// artifact contract the backend `load` path reads).
    fn write_config_dir(spec: &ModelSpec, tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("halo_sim_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut params_j = Vec::new();
        for (i, name) in spec.names.iter().enumerate() {
            let mut e = Json::obj();
            e.set("name", name.as_str())
                .set("shape", spec.shapes[i].iter().map(|&x| x as f64).collect::<Vec<f64>>())
                .set("offset", 0usize)
                .set("numel", spec.shapes[i].iter().product::<usize>())
                .set("linear", spec.linear[i]);
            params_j.push(e);
        }
        let mut cfg = Json::obj();
        cfg.set("vocab", spec.vocab)
            .set("d_model", spec.d_model)
            .set("n_layers", spec.n_layers)
            .set("n_heads", spec.n_heads)
            .set("d_ff", spec.d_ff)
            .set("seq_len", spec.seq_len);
        let mut meta = Json::obj();
        meta.set("config", cfg).set("params", Json::Arr(params_j));
        std::fs::write(dir.join("config.json"), meta.to_string_pretty()).unwrap();
        dir
    }

    #[test]
    fn backend_load_and_run_via_files() {
        // End-to-end through the Backend trait: a real artifact directory
        // with config.json + (empty) hlo.txt markers.
        let spec = tiny_spec();
        let dir = write_config_dir(&spec, "nll");
        std::fs::write(dir.join("nll_fp.hlo.txt"), "(sim backend marker)").unwrap();

        let backend = SimBackend;
        let exe = backend.load(&dir.join("nll_fp.hlo.txt")).unwrap();
        let inputs = tiny_inputs(&spec, 6);
        let out = exe.run(&refs(&inputs)).unwrap();
        assert_eq!(out.len(), 1);
        let got = out[0].get_first_element::<f32>().unwrap();
        let want = model_loss(&spec, &refs(&inputs), false).unwrap();
        assert_eq!(got, want);
        // Missing artifacts must error (the skip-cleanly contract).
        assert!(backend.load(&dir.join("grad.hlo.txt")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn backend_decode_step_matches_full_run() {
        // The Backend/Executable surface for KV-cached decode: load a fwd
        // graph, run one full pass, then replay the same window through
        // run_decode_step — identical logits rows.
        let spec = tiny_spec();
        let dir = write_config_dir(&spec, "fwd");
        std::fs::write(dir.join("fwd_fp.hlo.txt"), "(sim backend marker)").unwrap();
        let backend = SimBackend;
        assert!(backend.supports_incremental_decode());
        let exe = backend.load(&dir.join("fwd_fp.hlo.txt")).unwrap();
        assert!(exe.supports_incremental_decode());

        let mut inputs = tiny_inputs(&spec, 11);
        inputs.pop(); // drop the (b, s+1) token literal; fwd takes (b, s)
        let s = spec.seq_len;
        let toks: Vec<i32> = (0..s as i32).map(|t| (t * 3 + 1) % spec.vocab as i32).collect();
        let mut full_inputs = inputs.clone();
        full_inputs.push(Literal::i32(&toks, &[1, s]).unwrap());
        let full = exe.run(&refs(&full_inputs)).unwrap();
        let full_logits = full[0].as_f32().unwrap();

        let bufs: Vec<Buffer> = inputs.iter().map(|l| Buffer::Host(l.clone())).collect();
        let brefs: Vec<&Buffer> = bufs.iter().collect();
        let mut cache = KvCache::new(spec.n_layers, spec.d_model);
        let pre = exe.run_decode_step(&brefs, &toks[..s - 1], 0, &mut cache).unwrap();
        assert_eq!(pre.dims(), &[s - 1, spec.vocab]);
        let last = exe.run_decode_step(&brefs, &toks[s - 1..], s - 1, &mut cache).unwrap();
        assert_eq!(last.dims(), &[1, spec.vocab]);
        let got: Vec<f32> = pre
            .as_f32()
            .unwrap()
            .iter()
            .chain(last.as_f32().unwrap())
            .copied()
            .collect();
        assert_eq!(got.as_slice(), full_logits, "cached vs full logits");

        // The NLL graph must refuse incremental decode.
        std::fs::write(dir.join("nll_fp.hlo.txt"), "(sim backend marker)").unwrap();
        let nll = backend.load(&dir.join("nll_fp.hlo.txt")).unwrap();
        assert!(!nll.supports_incremental_decode());
        let mut c2 = KvCache::new(spec.n_layers, spec.d_model);
        assert!(nll.run_decode_step(&brefs, &[1], 0, &mut c2).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
