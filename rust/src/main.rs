//! `halo` — the L3 coordinator binary.
//!
//! Subcommands regenerate every table/figure of the paper (DESIGN.md
//! experiment index) and run the serving demo. See `halo help`.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use halo::experiments::{figs, table2, write_report};
use halo::mac::{profile::delay_histogram_ps, MacProfile};
use halo::runtime::Store;
use halo::util::cli::Args;

const HELP: &str = "\
halo — HALO (AAAI'26) reproduction: hardware-aware quantization + DVFS

USAGE: halo <command> [options]

COMMANDS
  mac profile [--samples N]
        Figs 4+5: per-weight MAC frequency/power profile → fig4_5.md
        (--samples: sampled transitions per weight, default 4096)
  mac histogram [--w N]... [--samples N]
        Fig 3: settle-time histogram per weight value → fig3.md
        (default weights: 64 and -127, the paper's example pair)
  quantize --model M [--method Q] [--tile T] [--calib-batches N]
        Quantize one trained model and report per-layer bits/error/
        tile classes (--method: fp16|rtn-w8|w8a8|w4a8|w3a8|
        smoothquant-w{8,4,3}|gptq|zq-local|zq-global|halo-{perf,acc,bal};
        default halo-bal. --tile: tile edge, default 128)
  table2 [--models a,b] [--max-batches N] [--calib-batches N]
        Table II end-to-end perplexity eval → table2.md
  fig8 | fig10 | fig11 [--tile T]
        Systolic simulator figures → fig8.md / fig10.md / fig11.md
  fig12 | fig13
        GPU simulator figures → fig12.md / fig13.md
  ablate dram|dvfs-overhead|derived-ladder
        Ablation studies → ablate_<name>.md
  serve --model M [--quant Q] [--shards N] [--requests R] [--max-new T]
        Sharded serving demo (quantize → route → continuous batching →
        KV-cached decode). --quant halo-bal|halo-perf|halo-acc executes
        natively on packed codebook tiles (integer W4A8 kernels + fused
        SpMV; never densifies) and reports the modeled DVFS speedup/energy
        next to wall-clock; --quant none (default) serves the
        dequantized dense weights. Decode is incremental against a
        per-request KV cache; --no-kv-cache falls back to full-prefix
        recompute (the equivalence oracle) for debugging.
  loadgen [--shards N] [--rps R] [--requests M] [--json FILE]
          [--quant Q --model M [--spec CFG]]
          [--chaos-seed S [--kill-prob P]]
        Paced serving load. Default: deterministic synthetic executor,
        no artifacts needed. With --quant: drives the packed quantized
        model from the artifact store instead (KV-cached continuous
        batching; --no-kv-cache for the recompute oracle). With
        --chaos-seed: injects a seeded fault schedule (shard kills,
        transient admit errors, enqueue delays) to exercise supervised
        shard recovery; the report counts restarts/retries and breaks
        sheds down by reason.
  all [--max-batches N]
        Regenerate every report → results/

OPTIONS
  --artifacts DIR   artifact root (default: ./artifacts or $HALO_ARTIFACTS)
  --out DIR         report output dir (default: ./results)

SERVING OPTIONS (serve / loadgen)
  --quant Q           packed-execution method (see serve above)
  --shards N          executor shards/threads (serve: 1, loadgen: 4)
  --max-new T         tokens to decode per request (default 1 / 4)
  --batch B           loadgen max batch size per shard (default 8)
  --batch-timeout-ms  loadgen batcher flush timeout (default 2)
  --queue-cap Q       per-shard admission bound, 0 = unbounded
  --deadline-ms D     shed requests older than D ms, 0 = no deadline
  --rps R             loadgen arrival rate, 0 = as fast as possible
  --prefix P          loadgen prefix length per request (default 12)
  --work W            loadgen busywork matmul side, synthetic only (48)
  --seed S            loadgen RNG seed (default 0x10AD)
  --json FILE         loadgen: write the full JSON report to FILE
  --tile T            quantization tile size under --quant (default 128)
  --spec CFG          speculative decoding on the variant ladder, e.g.
                      --spec drafter=halo-perf,k=4 (requires --quant):
                      the drafter variant proposes up to k tokens per
                      round through its own KV chain (drafting natively
                      on its packed tiles), the served
                      packed variant verifies them in one batched pass
                      and rolls its block table back to the accept
                      point. Emitted chains are bit-identical to
                      verifier-only decode; the report adds the
                      acceptance rate and drafter/verifier work split
  --no-kv-cache       decode by full-prefix recompute instead of the
                      per-request KV cache (debugging oracle;
                      incompatible with --spec)
  --kv-block-size B   rows per paged KV block (default 16); per-request
                      caches are carved from a per-shard block pool with
                      shared-prefix reuse across requests
  --kv-pool-blocks N  per-shard KV pool bound in blocks; 0 = unbounded
                      (default). A dry pool sheds requests as brown-out
                      backpressure instead of aborting
  --chaos-seed S      loadgen: install a seeded fault-injection schedule
                      (deterministic chaos; see DESIGN.md §Fault model)
  --kill-prob P       loadgen: per-step shard-kill probability under
                      --chaos-seed (default 0.02)

ENVIRONMENT
  HALO_FAILPOINTS     serve/loadgen: failpoint schedule, e.g.
                      \"shard.step=panic,0.02;queue.push=delay:1,0.3\"
                      (sites: shard.loop shard.begin shard.step
                      queue.push kvcache.grow sim.run)
  HALO_FAILPOINT_SEED seed for probabilistic failpoints (default 0)
";

fn main() -> Result<()> {
    let args = Args::from_env();
    let out = PathBuf::from(args.str_or("out", "results"));
    let t0 = Instant::now();
    match args.subcommand() {
        Some("mac") => cmd_mac(&args, &out)?,
        Some("quantize") => cmd_quantize(&args)?,
        Some("table2") => cmd_table2(&args, &out)?,
        Some("fig8") => {
            write_report(&out.join("fig8.md"), &figs::fig8(args.usize_or("tile", 128)?))?
        }
        Some("fig10") => {
            write_report(&out.join("fig10.md"), &figs::fig10(args.usize_or("tile", 128)?))?
        }
        Some("fig11") => write_report(&out.join("fig11.md"), &figs::fig11())?,
        Some("fig12") => write_report(&out.join("fig12.md"), &figs::fig12())?,
        Some("fig13") => write_report(&out.join("fig13.md"), &figs::fig13())?,
        Some("ablate") => cmd_ablate(&args, &out)?,
        Some("serve") => cmd_serve(&args)?,
        Some("loadgen") => cmd_loadgen(&args)?,
        Some("all") => cmd_all(&args, &out)?,
        Some("help") | None => {
            print!("{HELP}");
            return Ok(());
        }
        Some(other) => {
            eprint!("{HELP}");
            anyhow::bail!("unknown command `{other}` — full usage above");
        }
    }
    eprintln!("done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_mac(args: &Args, out: &std::path::Path) -> Result<()> {
    let sub = args.positional.get(1).map(|s| s.as_str());
    let profile = MacProfile::cached();
    match sub {
        Some("histogram") => {
            let ws = args.get_all("w");
            let ws: Vec<i8> = if ws.is_empty() {
                vec![64, -127] // the paper's Fig 3 pair
            } else {
                ws.iter()
                    .map(|s| {
                        s.parse().map_err(|_| {
                            anyhow::anyhow!(
                                "--w expects an i8 weight value (-128..=127), got {s:?}"
                            )
                        })
                    })
                    .collect::<Result<_>>()?
            };
            let samples = args.usize_or("samples", 4096)?;
            let mut md = String::from("## Fig 3 — settle-time histograms\n\n");
            for w in ws {
                md.push_str(&format!(
                    "### weight {w} (max {:.0} ps → {:.2} GHz)\n\n",
                    profile.delay_of(w),
                    profile.freq_of(w).min(99.0)
                ));
                for (ps, count) in delay_histogram_ps(w, samples, 3) {
                    md.push_str(&format!("{ps:7.0} ps: {count}\n"));
                }
                md.push('\n');
            }
            print!("{md}");
            write_report(&out.join("fig3.md"), &md)?;
        }
        _ => {
            let md = figs::mac_figures(profile);
            print!("{md}");
            write_report(&out.join("fig4_5.md"), &md)?;
            profile.save(&out.join("mac_profile.json"))?;
        }
    }
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    use halo::model::calibrate_fisher;
    use halo::quant::baselines::by_name;
    use halo::runtime::Runtime;

    let store = open_store(args)?;
    let model_name = args.str_or("model", "base").to_string();
    let method = args.str_or("method", "halo-bal");
    let tile = args.usize_or("tile", 128)?;
    let rt = Runtime::cpu()?;
    let model = store.model(&model_name)?;
    let calib = store.corpus_calib()?;
    let grads = calibrate_fisher(&rt, &model, &calib, 4)?;
    let profile = MacProfile::cached();
    let q = by_name(method, profile, tile)
        .ok_or_else(|| anyhow::anyhow!("unknown method {method}"))?;

    println!("# quantize {model_name} with {method} (tile {tile})\n");
    let mut total_bits = 0.0;
    let mut total_w = 0.0;
    for p in model.linear_params() {
        let w = p.as_matrix()?;
        let ctx = match grads.get(&p.name) {
            Some(g) => halo::quant::LayerCtx::with_grad(&p.name, g),
            None => halo::quant::LayerCtx::new(&p.name),
        };
        let res = q.quantize(&w, &ctx);
        let (fast, med, base) = res.class_counts(profile);
        println!(
            "{:<22} {:>4}x{:<4} bw={:.2} mse={:.2e} tiles fast/med/base={}/{}/{} sparse={}",
            p.name,
            w.rows,
            w.cols,
            res.bits_eff,
            res.dequant.mse(&w),
            fast,
            med,
            base,
            res.sparse_nnz
        );
        total_bits += res.bits_eff * w.numel() as f64;
        total_w += w.numel() as f64;
    }
    println!("\neffective bit-width (B_eff): {:.3}", total_bits / total_w);
    Ok(())
}

fn cmd_table2(args: &Args, out: &std::path::Path) -> Result<()> {
    let store = open_store(args)?;
    let models: Vec<String> = match args.get("models") {
        Some(s) => s.split(',').map(String::from).collect(),
        None => {
            let mut m = store.model_names()?;
            m.sort_by_key(|n| {
                ["tiny", "small", "base", "large"]
                    .iter()
                    .position(|x| x == n)
                    .unwrap_or(9)
            });
            m
        }
    };
    let max_batches = args.usize_or("max-batches", 24)?;
    let calib_batches = args.usize_or("calib-batches", 4)?;
    let rows = table2::run(&store, &models, table2::METHODS, max_batches, calib_batches)?;
    let md = table2::render(&rows, &models);
    println!("{md}");
    write_report(&out.join("table2.md"), &md)?;
    Ok(())
}

fn cmd_ablate(args: &Args, out: &std::path::Path) -> Result<()> {
    let what = args.positional.get(1).map(|s| s.as_str());
    let md = match what {
        Some("dram") => figs::ablate_dram(),
        Some("dvfs-overhead") => figs::ablate_dvfs_overhead(),
        Some("derived-ladder") => figs::ablate_derived_ladder(MacProfile::cached()),
        _ => anyhow::bail!("ablate dram|dvfs-overhead|derived-ladder"),
    };
    println!("{md}");
    write_report(
        &out.join(format!("ablate_{}.md", what.unwrap().replace('-', "_"))),
        &md,
    )
}

/// `--quant halo-bal|halo-perf|halo-acc|bal|perf|acc` → a packed-execution
/// variant; `none` (the default) → dense dequantized serving.
fn parse_quant_variant(s: &str) -> Result<Option<halo::quant::Variant>> {
    if s == "none" {
        return Ok(None);
    }
    halo::quant::Variant::parse(s.strip_prefix("halo-").unwrap_or(s))
        .map(Some)
        .ok_or_else(|| {
            anyhow::anyhow!("--quant must be none or halo-bal|halo-perf|halo-acc, got `{s}`")
        })
}

/// Per-shard paged KV block pools from the serving CLI flags. Built
/// *outside* the executor factories so a pool (and its shared-prefix
/// registry) survives supervisor respawns of its shard.
fn make_kv_pools(
    args: &Args,
    n_shards: usize,
    n_layers: usize,
    d_model: usize,
) -> Result<Vec<std::sync::Arc<halo::runtime::BlockPool>>> {
    use halo::runtime::{BlockPool, DEFAULT_BLOCK_ROWS};
    let block_rows = args.usize_or("kv-block-size", DEFAULT_BLOCK_ROWS)?.max(1);
    let max_blocks = args.usize_or("kv-pool-blocks", 0)?;
    Ok((0..n_shards)
        .map(|_| {
            std::sync::Arc::new(
                BlockPool::new(n_layers, d_model, block_rows, max_blocks).with_sharing(1024),
            )
        })
        .collect())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use halo::coordinator::server::GraphExecutor;
    use halo::coordinator::{
        BatcherConfig, Coordinator, CoordinatorConfig, QuantExecutor, Request,
    };
    use halo::dvfs::{Ladder, Schedule};
    use halo::model::calibrate_fisher;
    use halo::quant::{HaloConfig, HaloQuantizer, Quantizer, Variant};
    use halo::runtime::{PackedModel, Runtime};
    use std::collections::BTreeMap;
    use std::sync::Arc;
    use std::time::Duration;

    if halo::util::failpoint::install_from_env()? {
        eprintln!("[serve] fault-injection schedule installed from HALO_FAILPOINTS");
    }
    let store = open_store(args)?;
    let model_name = args.str_or("model", "base").to_string();
    let n_requests = args.usize_or("requests", 64)?;
    let n_shards = args.usize_or("shards", 1)?.max(1);
    let max_new = args.usize_or("max-new", 1)?.max(1);
    let queue_cap = args.usize_or("queue-cap", 0)?;
    let deadline_ms = args.u64_or("deadline-ms", 0)?;
    let tile = args.usize_or("tile", 128)?;
    let quant = parse_quant_variant(args.str_or("quant", "none"))?;
    let use_kv = !args.has("no-kv-cache");
    let spec_cfg = match args.get("spec") {
        Some(s) => Some(halo::coordinator::SpecConfig::parse(s)?),
        None => None,
    };
    anyhow::ensure!(
        spec_cfg.is_none() || quant.is_some(),
        "--spec requires a packed verifier: pass --quant perf|bal|acc"
    );
    anyhow::ensure!(
        spec_cfg.is_none() || use_kv,
        "--spec decodes through KV caches; drop --no-kv-cache"
    );

    // Calibrate + quantize once on the main thread, then share the result
    // across the shard factories.
    let rt = Runtime::cpu()?;
    let model = store.model(&model_name)?;
    let vocab = model.vocab;
    let eval_batch = model.eval_batch;
    let calib = store.corpus_calib()?;
    let grads = calibrate_fisher(&rt, &model, &calib, 2)?;
    let profile = MacProfile::cached();
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig::default(),
        shards: n_shards,
        queue_cap,
        default_deadline: if deadline_ms > 0 {
            Some(Duration::from_millis(deadline_ms))
        } else {
            None
        },
        ..CoordinatorConfig::default()
    };

    let coord = if let Some(variant) = quant {
        // Native quantized serving: every shard decodes directly on the
        // shared packed codebook tiles — dense f32 weights never exist.
        let packed = PackedModel::pack_artifacts(&model, variant, tile, &grads, profile)?;
        let cost = packed.cost(&Ladder::paper_systolic());
        eprintln!(
            "[serve] packed {} layers (halo-{}, tile {tile}), schedule transitions={}, shards={n_shards}",
            packed.n_packed(),
            variant.name(),
            packed.schedule.transitions()
        );
        eprintln!("[serve] cost model: {}", cost.summary());
        let pm = Arc::new(packed);
        let ss = Arc::new(pm.schedule.shard(n_shards));
        let pools = make_kv_pools(args, n_shards, pm.spec.n_layers, pm.spec.d_model)?;
        if let Some(sc) = spec_cfg {
            // Speculative serving: pack the drafter variant once and let
            // every shard draft natively on the shared packed tiles —
            // the integer W4A8 kernels beat the dense kernels, so the
            // packed drafter is the fast one. The served packed variant
            // stays the verifier, so emitted chains are bit-identical to
            // plain `--quant` serving.
            use halo::coordinator::{SpecExecutor, SpecVerifier};
            let drafter = Arc::new(PackedModel::pack_artifacts(
                &model, sc.drafter, tile, &grads, profile,
            )?);
            let dpools =
                make_kv_pools(args, n_shards, drafter.spec.n_layers, drafter.spec.d_model)?;
            eprintln!(
                "[serve] speculative: drafter=halo-{} (native packed), k={}",
                sc.drafter.name(),
                sc.k
            );
            Coordinator::start(cfg, move |shard| {
                let mut exec = SpecExecutor::from_packed(
                    drafter.clone(),
                    SpecVerifier::Packed(pm.clone()),
                    sc.k,
                    eval_batch,
                )?
                .with_schedule(ss[shard].clone());
                if let (Some(vp), Some(dp)) = (pools.get(shard), dpools.get(shard)) {
                    exec = exec.with_kv_pools(vp.clone(), dp.clone());
                }
                Ok(Box::new(exec) as Box<dyn halo::coordinator::BatchExecutor>)
            })
        } else {
            Coordinator::start(cfg, move |shard| {
                let mut exec =
                    QuantExecutor::with_schedule(pm.clone(), eval_batch, ss[shard].clone())
                        .with_kv_cache(use_kv);
                if use_kv {
                    if let Some(pool) = pools.get(shard) {
                        exec = exec.with_kv_pool(pool.clone());
                    }
                }
                Ok(Box::new(exec) as Box<dyn halo::coordinator::BatchExecutor>)
            })
        }
    } else {
        // Dense path: quantize, dequantize back to f32, substitute into
        // the lowered fwd graph (HALO-bal, the paper's deployment).
        let q = HaloQuantizer::new(HaloConfig::new(tile, Variant::Bal), profile);
        let mut replace = BTreeMap::new();
        let mut classes = Vec::new();
        for p in model.linear_params() {
            let w = p.as_matrix()?;
            let ctx = match grads.get(&p.name) {
                Some(g) => halo::quant::LayerCtx::with_grad(&p.name, g),
                None => halo::quant::LayerCtx::new(&p.name),
            };
            let res = q.quantize(&w, &ctx);
            for &f in &res.tile_freq_ghz {
                classes.push(halo::dvfs::classify(f, profile));
            }
            replace.insert(p.name.clone(), res.dequant);
        }
        let schedule = Schedule::cluster(&classes);
        eprintln!(
            "[serve] quantized {} tiles (dense dequant), schedule groups={} transitions={}, shards={n_shards}",
            classes.len(),
            schedule.groups.len(),
            schedule.transitions()
        );
        // Pool dims need the model spec; without one the executor serves
        // on the recompute path anyway, so skip pools rather than fail.
        let pools = match halo::runtime::sim::ModelSpec::load(&model.dir) {
            Ok(s) => make_kv_pools(args, n_shards, s.n_layers, s.d_model)?,
            Err(_) => Vec::new(),
        };
        let model = Arc::new(model);
        let replace = Arc::new(replace);
        let ss = Arc::new(schedule.shard(n_shards));
        Coordinator::start(cfg, move |shard| {
            // Each shard owns its runtime + resident parameter buffers
            // (PJRT handles never cross threads) and applies its own
            // schedule slice.
            let rt = Runtime::cpu()?;
            let mut exec = GraphExecutor::new(rt, &model, &replace, ss[shard].clone())?
                .with_kv_cache(use_kv);
            if use_kv {
                if let Some(pool) = pools.get(shard) {
                    exec = exec.with_kv_pool(pool.clone());
                }
            }
            Ok(Box::new(exec) as Box<dyn halo::coordinator::BatchExecutor>)
        })
    };

    // Fire a synthetic request stream sampled from the corpus.
    let stream = store.corpus_eval("wikisyn")?;
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_requests {
        let start = (i * 37) % (stream.len() - 64);
        let prefix: Vec<i32> =
            stream[start..start + 32].iter().map(|&t| t as i32).collect();
        rxs.push(coord.submit_or_shed(Request::new(prefix).max_new(max_new)));
    }
    let (mut ok, mut shed) = (0, 0);
    for rx in rxs {
        let resp = rx.recv()?;
        if resp.shed {
            shed += 1;
            continue;
        }
        anyhow::ensure!(resp.tokens.len() == max_new, "short decode");
        anyhow::ensure!(resp.tokens.iter().all(|t| (0..vocab as i32).contains(t)));
        ok += 1;
    }
    let wall = t0.elapsed();
    anyhow::ensure!(
        ok > 0 || n_requests == 0,
        "all {n_requests} requests shed — no healthy executor shard"
    );
    let merged = coord.merged_snapshot();
    println!(
        "[serve] {ok}/{n_requests} served ({shed} shed) in {:.2}s — {:.1} tokens/s",
        wall.as_secs_f64(),
        merged.tokens_per_sec(wall)
    );
    println!("[serve] {}", merged.summary());
    if merged.kv_blocks_peak > 0 {
        println!(
            "[serve] kv pool: in_use={} peak={} shared_hits={}/{} evictions={} refusals={}",
            merged.kv_blocks_in_use,
            merged.kv_blocks_peak,
            merged.kv_shared_hits,
            merged.kv_prefix_lookups,
            merged.kv_evictions,
            merged.kv_pool_refusals
        );
    }
    for (s, sm) in coord.shard_metrics().iter().enumerate() {
        println!("[serve]   shard {s}: {}", sm.summary());
    }
    coord.shutdown()?;
    Ok(())
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    use halo::coordinator::loadgen::{self, LoadgenConfig};
    use std::time::Duration;

    if halo::util::failpoint::install_from_env()? {
        eprintln!("[loadgen] fault-injection schedule installed from HALO_FAILPOINTS");
    }
    let deadline_ms = args.u64_or("deadline-ms", 0)?;
    let quant = parse_quant_variant(args.str_or("quant", "none"))?;
    let spec_cfg = match args.get("spec") {
        Some(s) => Some(halo::coordinator::SpecConfig::parse(s)?),
        None => None,
    };
    anyhow::ensure!(
        spec_cfg.is_none() || quant.is_some(),
        "--spec requires a packed verifier: pass --quant perf|bal|acc"
    );
    anyhow::ensure!(
        spec_cfg.is_none() || !args.has("no-kv-cache"),
        "--spec decodes through KV caches; drop --no-kv-cache"
    );
    let cfg = LoadgenConfig {
        shards: args.usize_or("shards", 4)?.max(1),
        batch_size: args.usize_or("batch", 8)?.max(1),
        batch_timeout: Duration::from_millis(args.u64_or("batch-timeout-ms", 2)?),
        queue_cap: args.usize_or("queue-cap", 0)?,
        deadline: if deadline_ms > 0 { Some(Duration::from_millis(deadline_ms)) } else { None },
        requests: args.usize_or("requests", 512)?,
        rps: args.f64_or("rps", 0.0)?,
        max_new_tokens: args.usize_or("max-new", 4)?.max(1),
        prefix_len: args.usize_or("prefix", 12)?.max(1),
        work_dim: args.usize_or("work", 48)?.max(1),
        seed: args.u64_or("seed", 0x10AD)?,
        chaos_seed: match args.get("chaos-seed") {
            Some(s) => Some(s.parse::<u64>().map_err(|e| {
                anyhow::anyhow!("--chaos-seed must be an integer, got `{s}`: {e}")
            })?),
            None => None,
        },
        kill_prob: args.f64_or("kill-prob", 0.02)?,
    };
    if cfg.chaos_seed.is_some() {
        eprintln!(
            "[loadgen] chaos mode: seed={} kill_prob={} (shard kills, admit errors, push delays)",
            cfg.chaos_seed.unwrap_or(0),
            cfg.kill_prob
        );
    }

    let report = if let Some(variant) = quant {
        // Real quantized model behind the same paced-arrival harness:
        // every shard decodes on the shared packed tiles.
        use halo::coordinator::QuantExecutor;
        use halo::model::calibrate_fisher;
        use halo::runtime::{PackedModel, Runtime};
        use std::sync::Arc;

        let store = open_store(args)?;
        let model = store.model(args.str_or("model", "base"))?;
        let rt = Runtime::cpu()?;
        let calib = store.corpus_calib()?;
        let grads = calibrate_fisher(&rt, &model, &calib, 1)?;
        let tile = args.usize_or("tile", 128)?;
        let packed = PackedModel::pack_artifacts(
            &model,
            variant,
            tile,
            &grads,
            MacProfile::cached(),
        )?;
        eprintln!(
            "[loadgen] packed {} layers (halo-{}, tile {tile}); {}",
            packed.n_packed(),
            variant.name(),
            packed.cost(&halo::dvfs::Ladder::paper_systolic()).summary()
        );
        let vocab = packed.spec.vocab;
        let batch = cfg.batch_size;
        let ss = Arc::new(packed.schedule.shard(cfg.shards));
        let pm = Arc::new(packed);
        let max_new = cfg.max_new_tokens;
        // Verify shape/range on every response, and re-derive the exact
        // greedy decode chain against the packed model for a bounded
        // sample — enough to catch a broken decode loop without doubling
        // the whole run's compute client-side.
        const EXACT_CHECKS: usize = 32;
        let use_kv = !args.has("no-kv-cache");
        let pmv = pm.clone();
        let exact_left = std::cell::Cell::new(EXACT_CHECKS);
        // Judge responses against the decode path the shards actually run:
        // the cached ring decode by default, the O(S²) recompute oracle
        // under --no-kv-cache (the two are bit-identical until a context
        // slide, which ring re-basing handles differently by design).
        let verify = move |p: &[i32], tokens: &[i32], _m: usize| {
            if tokens.len() != max_new
                || !tokens.iter().all(|&t| (0..vocab as i32).contains(&t))
            {
                return false;
            }
            if exact_left.get() == 0 {
                return true;
            }
            exact_left.set(exact_left.get() - 1);
            let want = if use_kv {
                pmv.decode_greedy(p, max_new)
            } else {
                pmv.decode_greedy_recompute(p, max_new)
            };
            match want {
                Ok(want) => want == tokens,
                Err(_) => false,
            }
        };
        let pools = make_kv_pools(args, cfg.shards, pm.spec.n_layers, pm.spec.d_model)?;
        if let Some(sc) = spec_cfg {
            // Speculative loadgen: same verifier-side oracle as above — the
            // exactness contract means spec-decoded chains must still match
            // `decode_greedy` bit for bit, so `verify` needs no changes.
            use halo::coordinator::{SpecExecutor, SpecVerifier};
            let drafter = Arc::new(PackedModel::pack_artifacts(
                &model,
                sc.drafter,
                tile,
                &grads,
                MacProfile::cached(),
            )?);
            let dpools =
                make_kv_pools(args, cfg.shards, drafter.spec.n_layers, drafter.spec.d_model)?;
            eprintln!(
                "[loadgen] speculative: drafter=halo-{} (native packed), k={}",
                sc.drafter.name(),
                sc.k
            );
            loadgen::run_with(&cfg, vocab, &verify, move |shard| {
                let mut exec = SpecExecutor::from_packed(
                    drafter.clone(),
                    SpecVerifier::Packed(pm.clone()),
                    sc.k,
                    batch,
                )?
                .with_schedule(ss[shard].clone());
                if let (Some(vp), Some(dp)) = (pools.get(shard), dpools.get(shard)) {
                    exec = exec.with_kv_pools(vp.clone(), dp.clone());
                }
                Ok(Box::new(exec) as Box<dyn halo::coordinator::BatchExecutor>)
            })?
        } else {
            loadgen::run_with(&cfg, vocab, &verify, move |shard| {
                let mut exec = QuantExecutor::with_schedule(pm.clone(), batch, ss[shard].clone())
                    .with_kv_cache(use_kv);
                if use_kv {
                    if let Some(pool) = pools.get(shard) {
                        exec = exec.with_kv_pool(pool.clone());
                    }
                }
                Ok(Box::new(exec) as Box<dyn halo::coordinator::BatchExecutor>)
            })?
        }
    } else {
        loadgen::run(&cfg)?
    };
    println!("[loadgen] {}", report.summary());
    for (s, m) in report.per_shard.iter().enumerate() {
        println!("[loadgen]   shard {s}: {}", m.summary());
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().to_string_pretty())?;
        println!("[loadgen] wrote {path}");
    }
    Ok(())
}

fn cmd_all(args: &Args, out: &std::path::Path) -> Result<()> {
    let profile = MacProfile::cached();
    write_report(&out.join("fig4_5.md"), &figs::mac_figures(profile))?;
    write_report(&out.join("fig8.md"), &figs::fig8(128))?;
    write_report(&out.join("fig10.md"), &figs::fig10(128))?;
    write_report(&out.join("fig11.md"), &figs::fig11())?;
    let (f12, f13) = figs::fig12_13();
    write_report(&out.join("fig12.md"), &f12)?;
    write_report(&out.join("fig13.md"), &f13)?;
    write_report(&out.join("ablate_dram.md"), &figs::ablate_dram())?;
    write_report(&out.join("ablate_dvfs_overhead.md"), &figs::ablate_dvfs_overhead())?;
    write_report(
        &out.join("ablate_derived_ladder.md"),
        &figs::ablate_derived_ladder(profile),
    )?;
    cmd_table2(args, out)?;
    Ok(())
}

fn open_store(args: &Args) -> Result<Store> {
    match args.get("artifacts") {
        Some(dir) => Store::open(dir),
        None => Store::open_default(),
    }
}
