//! Generators for the paper's performance/energy figures (8–13) and the
//! ablations. All run the simulators over the paper-scale model shapes.

use crate::dvfs::Ladder;
use crate::gpu::{GpuConfig, GpuSim};
use crate::mac::MacProfile;
use crate::systolic::{SimConfig, SimReport, Simulator};
use crate::workload::{ModelShapes, Phase};

use super::markdown_table;

pub const FIG_METHODS: &[&str] =
    &["fp16", "w8a8", "w4a8", "w3a8", "halo-perf", "halo-acc", "halo-bal"];

/// One (model, method) simulation cell.
#[derive(Debug, Clone)]
pub struct Cell {
    pub model: String,
    pub method: String,
    pub time_s: f64,
    pub energy_j: f64,
    pub detail: String,
}

fn systolic_cells(tile: usize, ladder: Ladder) -> Vec<Cell> {
    let sim = Simulator::new(SimConfig { ladder, ..SimConfig::default() });
    let mut out = Vec::new();
    for model in ModelShapes::paper_models() {
        for &m in FIG_METHODS {
            let r: SimReport = sim.run_method(&model, Phase::prefill(), m, tile, 0xF16);
            out.push(Cell {
                model: model.name.into(),
                method: m.into(),
                time_s: r.time_s,
                energy_j: r.energy.total(),
                detail: format!(
                    "core_dyn={:.2} core_st={:.2} buf={:.2} mem={:.2} (J), transitions={}",
                    r.energy.core_dynamic,
                    r.energy.core_static,
                    r.energy.buffer_dynamic + r.energy.buffer_static,
                    r.energy.mem_dynamic + r.energy.mem_static,
                    r.dvfs_transitions
                ),
            });
        }
    }
    out
}

fn normalize(cells: &[Cell], value: impl Fn(&Cell) -> f64) -> Vec<Vec<String>> {
    let models: Vec<String> = {
        let mut m: Vec<String> = cells.iter().map(|c| c.model.clone()).collect();
        m.dedup();
        m
    };
    models
        .iter()
        .map(|model| {
            let base = cells
                .iter()
                .find(|c| &c.model == model && c.method == "fp16")
                .map(&value)
                .unwrap_or(1.0);
            let mut row = vec![model.clone()];
            for &m in FIG_METHODS {
                let c = cells
                    .iter()
                    .find(|c| &c.model == model && c.method == m)
                    .expect("cell");
                row.push(format!("{:.3}", value(c) / base));
            }
            row
        })
        .collect()
}

fn headers() -> Vec<&'static str> {
    let mut h = vec!["model"];
    h.extend(FIG_METHODS);
    h
}

/// Fig 8: normalized systolic execution time (lower = faster).
pub fn fig8(tile: usize) -> String {
    let cells = systolic_cells(tile, Ladder::paper_systolic());
    let rows = normalize(&cells, |c| c.time_s);
    format!(
        "## Fig 8 — normalized systolic execution time (tile={tile}, FP16=1.0)\n\n{}",
        markdown_table(&headers(), &rows)
    )
}

/// Fig 10: normalized systolic energy.
pub fn fig10(tile: usize) -> String {
    let cells = systolic_cells(tile, Ladder::paper_systolic());
    let rows = normalize(&cells, |c| c.energy_j);
    let detail: Vec<Vec<String>> = cells
        .iter()
        .filter(|c| c.model == "llama2-7b")
        .map(|c| vec![c.method.clone(), c.detail.clone()])
        .collect();
    format!(
        "## Fig 10 — normalized systolic energy (tile={tile}, FP16=1.0)\n\n{}\n\
         ### decomposition (llama2-7b)\n\n{}",
        markdown_table(&headers(), &rows),
        markdown_table(&["method", "breakdown"], &detail)
    )
}

/// Fig 11: HALO-bal execution time across tile sizes 128/64/32.
pub fn fig11() -> String {
    let sim = Simulator::new(SimConfig::default());
    let mut rows = Vec::new();
    for model in ModelShapes::paper_models() {
        let mut row = vec![model.name.to_string()];
        let t128 = sim
            .run_method(&model, Phase::prefill(), "halo-bal", 128, 0xF16)
            .time_s;
        for tile in [128usize, 64, 32] {
            let t = sim
                .run_method(&model, Phase::prefill(), "halo-bal", tile, 0xF16)
                .time_s;
            row.push(format!("{:.3}", t / t128));
        }
        rows.push(row);
    }
    format!(
        "## Fig 11 — HALO-bal systolic time vs tile size (tile128=1.0)\n\n{}",
        markdown_table(&["model", "tile=128", "tile=64", "tile=32"], &rows)
    )
}

/// Shared GPU sweep behind Figs 12 and 13: (normalized-time rows,
/// normalized-energy rows). [`fig12_13`] renders both from one sweep;
/// the per-figure entry points each pay for their own.
fn gpu_rows() -> (Vec<Vec<String>>, Vec<Vec<String>>) {
    let sim = GpuSim::new(GpuConfig::default());
    let mut time_rows = Vec::new();
    let mut energy_rows = Vec::new();
    for model in ModelShapes::paper_models() {
        let base = sim.run_method(&model, Phase::decode(8), "w8a8", 128, 0xF16);
        let mut trow = vec![model.name.to_string()];
        let mut erow = vec![model.name.to_string()];
        for &m in FIG_METHODS {
            let r = sim.run_method(&model, Phase::decode(8), m, 128, 0xF16);
            trow.push(format!("{:.3}", r.time_s / base.time_s));
            erow.push(format!(
                "{:.3} (c{:.2}/s{:.2}/d{:.2})",
                r.energy_total() / base.energy_total(),
                r.energy_constant / base.energy_total(),
                r.energy_static / base.energy_total(),
                r.energy_dynamic / base.energy_total(),
            ));
        }
        time_rows.push(trow);
        energy_rows.push(erow);
    }
    (time_rows, energy_rows)
}

fn render_fig12(time_rows: &[Vec<String>]) -> String {
    format!(
        "## Fig 12 — normalized GPU execution time (W8A8=1.0, decode batch=8)\n\n{}",
        markdown_table(&headers(), time_rows)
    )
}

fn render_fig13(energy_rows: &[Vec<String>]) -> String {
    format!(
        "## Fig 13 — normalized GPU energy (W8A8=1.0; constant/static/dynamic)\n\n{}",
        markdown_table(&headers(), energy_rows)
    )
}

/// Fig 12: normalized GPU execution time.
pub fn fig12() -> String {
    let (time_rows, _) = gpu_rows();
    render_fig12(&time_rows)
}

/// Fig 13: normalized GPU energy with the constant/static/dynamic split.
pub fn fig13() -> String {
    let (_, energy_rows) = gpu_rows();
    render_fig13(&energy_rows)
}

/// Both GPU figures from a single simulator sweep — (fig12 md, fig13 md).
/// `halo all` uses this so the sweep runs once.
pub fn fig12_13() -> (String, String) {
    let (time_rows, energy_rows) = gpu_rows();
    (render_fig12(&time_rows), render_fig13(&energy_rows))
}

/// Fig 3/4/5 data: MAC circuit profile.
pub fn mac_figures(profile: &MacProfile) -> String {
    let mut rows = Vec::new();
    for w in [-128i8, -127, -64, -32, -16, -4, -1, 0, 1, 2, 4, 16, 64, 112, 127] {
        rows.push(vec![
            format!("{w}"),
            format!("{:.0}", profile.delay_of(w)),
            format!("{:.2}", profile.freq_of(w).min(99.0)),
            format!("{:.1}", profile.toggles_of(w)),
            format!("{:.3}", profile.energy_of(w)),
        ]);
    }
    format!(
        "## Figs 4+5 — per-weight MAC profile (selected weights)\n\n{}\n\
         fast codebook (9): {:?} → {:.2} GHz derived\n\
         med codebook (16): {:?} → {:.2} GHz derived\n\
         base (full int8 range): {:.2} GHz (calibrated)\n",
        markdown_table(&["weight", "delay (ps)", "freq (GHz)", "mean toggles", "E/op (pJ)"], &rows),
        profile.codebook_fast,
        profile.f_fast_ghz,
        profile.codebook_med,
        profile.f_med_ghz,
        profile.f_base_ghz
    )
}

/// §V ablation: DRAM traffic reduction from index-domain weight storage.
pub fn ablate_dram() -> String {
    let sim = Simulator::new(SimConfig::default());
    let mut rows = Vec::new();
    for model in ModelShapes::paper_models() {
        let w8 = sim.run_method(&model, Phase::prefill(), "w8a8", 128, 1);
        let halo = sim.run_method(&model, Phase::prefill(), "halo-bal", 128, 1);
        rows.push(vec![
            model.name.to_string(),
            format!("{:.1}", w8.weight_bytes / 1e9),
            format!("{:.1}", halo.weight_bytes / 1e9),
            format!("{:.2}%", (1.0 - halo.weight_bytes / w8.weight_bytes) * 100.0),
        ]);
    }
    format!(
        "## Ablation — weight DRAM traffic (paper §V claims 59.06% reduction with encoder/decoder)\n\n{}",
        markdown_table(&["model", "w8a8 (GB)", "halo-bal (GB)", "reduction"], &rows)
    )
}

/// Ablation: paper DVFS ladder vs the ladder derived from our gate model.
pub fn ablate_derived_ladder(profile: &MacProfile) -> String {
    let mut rows = Vec::new();
    for (name, ladder) in [
        ("paper", Ladder::paper_systolic()),
        ("derived", Ladder::derived(profile)),
    ] {
        let cells = systolic_cells(128, ladder);
        let w8 = cells
            .iter()
            .find(|c| c.model == "llama2-7b" && c.method == "w8a8")
            .unwrap()
            .time_s;
        let halo = cells
            .iter()
            .find(|c| c.model == "llama2-7b" && c.method == "halo-bal")
            .unwrap()
            .time_s;
        rows.push(vec![
            name.to_string(),
            format!("{:.1}ms", w8 * 1e3),
            format!("{:.1}ms", halo * 1e3),
            format!("{:.2}x", w8 / halo),
        ]);
    }
    format!(
        "## Ablation — DVFS ladder source (llama2-7b prefill): the paper's PrimeTime \
         spread vs our gate model's (DESIGN.md §Substitutions)\n\n{}",
        markdown_table(&["ladder", "w8a8", "halo-bal", "halo speedup"], &rows)
    )
}

/// DVFS transition overhead ablation (§III-C3).
pub fn ablate_dvfs_overhead() -> String {
    let sim = Simulator::new(SimConfig::default());
    let mut rows = Vec::new();
    for model in ModelShapes::paper_models() {
        let r = sim.run_method(&model, Phase::prefill(), "halo-bal", 128, 1);
        let overhead = r.dvfs_transitions as f64 * crate::dvfs::TRANSITION_S;
        rows.push(vec![
            model.name.to_string(),
            format!("{}", r.dvfs_transitions),
            format!("{:.1}µs", overhead * 1e6),
            format!("{:.1}ms", r.time_s * 1e3),
            format!("{:.4}%", overhead / r.time_s * 100.0),
        ]);
    }
    format!(
        "## Ablation — DVFS transition overhead (class-clustered schedule, §III-C3)\n\n{}",
        markdown_table(
            &["model", "transitions", "overhead", "inference", "fraction"],
            &rows
        )
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_contains_all_models_and_methods() {
        let md = fig8(128);
        for m in ["llama2-7b", "llama2-13b", "opt-1.3b", "opt-30b"] {
            assert!(md.contains(m), "{m}");
        }
        assert!(md.contains("halo-bal"));
    }

    #[test]
    fn fig11_tile32_fastest() {
        let md = fig11();
        // Every row: tile=32 ratio < 1.0 (strictly faster than 128).
        for line in md.lines().filter(|l| l.starts_with("| llama") || l.starts_with("| opt")) {
            let cols: Vec<&str> = line.split('|').map(|s| s.trim()).collect();
            let t32: f64 = cols[4].parse().unwrap();
            assert!(t32 < 1.0, "{line}");
        }
    }

    #[test]
    fn dram_ablation_shows_reduction() {
        let md = ablate_dram();
        assert!(md.contains('%'));
        // HALO must cut weight traffic by >40% vs W8A8.
        for line in md.lines().filter(|l| l.starts_with("| llama2-7b")) {
            let cols: Vec<&str> = line.split('|').map(|s| s.trim()).collect();
            let red: f64 = cols[4].trim_end_matches('%').parse().unwrap();
            assert!(red > 40.0, "{line}");
        }
    }
}
