//! Experiment harness: one generator per paper table/figure (DESIGN.md
//! experiment index). Each returns structured rows and renders markdown;
//! the CLI writes them under `results/`.

pub mod figs;
pub mod table2;

use std::path::Path;

/// Write a report file, creating `results/` as needed.
pub fn write_report(path: &Path, content: &str) -> crate::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Render rows as a GitHub-flavored markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push_str("| ");
    s.push_str(&headers.join(" | "));
    s.push_str(" |\n|");
    for _ in headers {
        s.push_str("---|");
    }
    s.push('\n');
    for row in rows {
        s.push_str("| ");
        s.push_str(&row.join(" | "));
        s.push_str(" |\n");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let md = markdown_table(
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        );
        assert_eq!(md.lines().count(), 4);
        assert!(md.contains("| 1 | 2 |"));
    }
}
