//! Table II: perplexity across methods × models × corpora, evaluated
//! end-to-end through the runtime-backend graphs (sim or PJRT) with
//! quantized weights substituted.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::mac::MacProfile;
use crate::model::{calibrate_fisher, Evaluator};
use crate::quant::baselines::by_name;
use crate::quant::Matrix;
use crate::runtime::{Runtime, Store};

use super::markdown_table;

/// One Table II row group for a model.
#[derive(Debug, Clone)]
pub struct Row {
    pub method: String,
    pub model: String,
    pub corpus: String,
    pub ppl: f64,
    pub bits: f64,
}

/// Methods in presentation order (paper Table II).
pub const METHODS: &[&str] = &[
    "fp16",
    "rtn-w8",
    "rtn-w4",
    "rtn-w3",
    "smoothquant-w8",
    "smoothquant-w4",
    "smoothquant-w3",
    "gptq",
    "zq-local",
    "zq-global",
    "halo-perf",
    "halo-acc",
    "halo-bal",
];

/// HALO-bal tile-size sweep rows (paper: tile 128/64/32).
pub const TILE_SWEEP: &[usize] = &[128, 64, 32];

/// Run the full table for the given models (default: all in the store).
pub fn run(
    store: &Store,
    models: &[String],
    methods: &[&str],
    max_batches: usize,
    calib_batches: usize,
) -> Result<Vec<Row>> {
    let rt = Runtime::cpu()?;
    let profile = MacProfile::cached();
    let mut rows = Vec::new();

    for model_name in models {
        let model = store.model(model_name)?;
        let ev = Evaluator::new(&rt, &model)?;
        let calib = store.corpus_calib()?;
        let grads: BTreeMap<String, Matrix> =
            calibrate_fisher(&rt, &model, &calib, calib_batches)?;
        eprintln!("[table2] {model_name}: fisher calibrated over {calib_batches} batches");

        for corpus in ["wikisyn", "c4syn"] {
            let stream = store.corpus_eval(corpus)?;
            for &method in methods {
                let row = if method == "fp16" {
                    let r = ev.eval_fp16(&stream, corpus, max_batches)?;
                    Row {
                        method: r.method,
                        model: model_name.clone(),
                        corpus: corpus.into(),
                        ppl: r.ppl,
                        bits: 16.0,
                    }
                } else {
                    let q = by_name(method, profile, 128)
                        .ok_or_else(|| anyhow::anyhow!("unknown method {method}"))?;
                    let r =
                        ev.eval_quantizer(q.as_ref(), &grads, &stream, corpus, max_batches, true)?;
                    Row {
                        method: r.method,
                        model: model_name.clone(),
                        corpus: corpus.into(),
                        ppl: r.ppl,
                        bits: r.bits_eff,
                    }
                };
                eprintln!(
                    "[table2] {model_name}/{corpus}/{method}: ppl {:.2} (bw {:.2})",
                    row.ppl, row.bits
                );
                rows.push(row);
            }
            // HALO tile-size sweep (bal variant), paper Table II bottom.
            for &tile in TILE_SWEEP.iter().skip(1) {
                let q = by_name("halo-bal", profile, tile).unwrap();
                let r =
                    ev.eval_quantizer(q.as_ref(), &grads, &stream, corpus, max_batches, true)?;
                eprintln!(
                    "[table2] {model_name}/{corpus}/halo-bal-t{tile}: ppl {:.2} (bw {:.2})",
                    r.ppl, r.bits_eff
                );
                rows.push(Row {
                    method: format!("halo-bal-t{tile}"),
                    model: model_name.clone(),
                    corpus: corpus.into(),
                    ppl: r.ppl,
                    bits: r.bits_eff,
                });
            }
        }
    }
    Ok(rows)
}

/// Render in the paper's layout: methods × (models per corpus).
pub fn render(rows: &[Row], models: &[String]) -> String {
    let mut methods: Vec<String> = Vec::new();
    for r in rows {
        if !methods.contains(&r.method) {
            methods.push(r.method.clone());
        }
    }
    let mut headers: Vec<String> = vec!["PPL↓ (BW)".into()];
    for corpus in ["wikisyn", "c4syn"] {
        for m in models {
            headers.push(format!("{corpus}/{m}"));
        }
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();

    let mut out_rows = Vec::new();
    for method in &methods {
        let mut row = vec![method.clone()];
        for corpus in ["wikisyn", "c4syn"] {
            for m in models {
                let cell = rows
                    .iter()
                    .find(|r| &r.method == method && &r.model == m && r.corpus == corpus);
                row.push(match cell {
                    Some(r) if r.ppl > 9999.0 => format!(">1e4 ({:.2})", r.bits),
                    Some(r) => format!("{:.2} ({:.2})", r.ppl, r.bits),
                    None => "—".into(),
                });
            }
        }
        out_rows.push(row);
    }
    format!(
        "## Table II — perplexity (lower is better), effective weight bits in parens\n\n{}",
        markdown_table(&hdr_refs, &out_rows)
    )
}
