//! halo-lint: repo-specific static analysis over `rust/src` (offline
//! build: no `syn`, so the scanner is a hand-rolled lexer that blanks
//! comments and string/char literals before pattern matching).
//!
//! Rules (see DESIGN.md §Concurrency model & static analysis):
//!
//! - **no-panic-serving-path** — no `.unwrap()`, `.expect(`, `panic!`,
//!   `unreachable!`, `todo!`, `unimplemented!` in non-test code of the
//!   serving path (`coordinator/` plus `runtime/{qkernels,kvcache,sim}.rs`);
//!   a panicking worker takes a whole shard with it, so every failure there
//!   must shed or return an error instead. Unchecked indexing (`x[i]`,
//!   `x[a..b]`) is additionally flagged in `coordinator/` — the runtime
//!   kernel files are index-dominated numeric code whose bounds are
//!   structural; they are exercised under Miri in CI instead.
//! - **sync-via-shim** — no direct `std::sync::Mutex`/`Condvar` outside
//!   `util/sync/`; everything must go through the shim so the model
//!   checker can interpose (`--cfg loom` proves the test models do).
//! - **no-unbounded-retry** — a loop header in `coordinator/` non-test
//!   code that names retry work (`retry`/`attempt`/`respawn`/`restart`)
//!   must reference its bound (`max`/`budget`/`cap`/`limit`) on the same
//!   line; the shard supervisor's recovery loops must never be able to
//!   spin forever, so an unbounded-looking retry loop is a finding unless
//!   audited in `lint_allow.toml`.
//! - **no-undocumented-unsafe** — every `unsafe` keyword needs a
//!   `// SAFETY:` comment within the preceding 10 lines.
//! - **missing-docs-inventory** — the set of `#[allow(missing_docs)]`
//!   module allows in `lib.rs` must equal the audited list in
//!   `lint_allow.toml` (a new allow is a docs-debt regression → error;
//!   a removed one leaves a stale inventory entry → warning).
//!
//! Audited exceptions live in `lint_allow.toml` at the repo root: each
//! `[[allow]]` entry names a rule, a file suffix, a `contains` substring
//! of the offending line, and a one-line `why`. Unused entries warn so
//! the allowlist can't rot. Exit status: 1 if any finding survives the
//! allowlist, 0 otherwise.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

// ---------------------------------------------------------------------------
// Findings and scope
// ---------------------------------------------------------------------------

/// One rule violation at a specific line.
#[derive(Debug, Clone, PartialEq)]
struct Finding {
    rule: &'static str,
    /// Path relative to `rust/src`, forward slashes.
    file: String,
    /// 1-based.
    line: usize,
    msg: String,
    /// Raw (unblanked) source line, for allowlist matching and display.
    snippet: String,
}

const RULE_PANIC: &str = "no-panic-serving-path";
const RULE_SYNC: &str = "sync-via-shim";
const RULE_UNSAFE: &str = "no-undocumented-unsafe";
const RULE_DOCS: &str = "missing-docs-inventory";
const RULE_RETRY: &str = "no-unbounded-retry";

/// Serving-path files beyond `coordinator/` (repo-relative to `rust/src`).
const SERVING_RUNTIME_FILES: &[&str] =
    &["runtime/qkernels.rs", "runtime/kvcache.rs", "runtime/sim.rs"];

fn in_serving_scope(rel: &str) -> bool {
    rel.starts_with("coordinator/") || SERVING_RUNTIME_FILES.contains(&rel)
}

fn in_indexing_scope(rel: &str) -> bool {
    rel.starts_with("coordinator/")
}

fn in_shim(rel: &str) -> bool {
    rel.starts_with("util/sync/") || rel == "util/sync.rs"
}

// ---------------------------------------------------------------------------
// Lexer: blank comments and literals, preserving line structure
// ---------------------------------------------------------------------------

/// Return a copy of `src` with every comment, string/byte-string literal
/// (including raw strings) and char literal replaced by spaces. Newlines
/// are preserved, so line/column positions survive. Lifetimes (`'a`) are
/// left intact.
fn blank_noncode(src: &str) -> String {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let blank = |out: &mut [u8], from: usize, to: usize| {
        for slot in out.iter_mut().take(to.min(n)).skip(from) {
            if *slot != b'\n' {
                *slot = b' ';
            }
        }
    };
    let mut i = 0;
    while i < n {
        match b[i] {
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                let start = i;
                while i < n && b[i] != b'\n' {
                    i += 1;
                }
                blank(&mut out, start, i);
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                let start = i;
                let mut depth = 1usize;
                i += 2;
                while i < n && depth > 0 {
                    if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                blank(&mut out, start, i);
            }
            b'"' => {
                let start = i;
                i += 1;
                while i < n {
                    if b[i] == b'\\' {
                        i += 2;
                    } else if b[i] == b'"' {
                        i += 1;
                        break;
                    } else {
                        i += 1;
                    }
                }
                blank(&mut out, start, i);
            }
            b'r' if i + 1 < n && (b[i + 1] == b'"' || b[i + 1] == b'#') => {
                // Raw string r"..." / r#"..."# (any hash depth).
                let mut j = i + 1;
                let mut hashes = 0usize;
                while j < n && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == b'"' {
                    let start = i;
                    j += 1;
                    'close: while j < n {
                        if b[j] == b'"' {
                            let mut k = j + 1;
                            let mut h = 0usize;
                            while k < n && h < hashes && b[k] == b'#' {
                                h += 1;
                                k += 1;
                            }
                            if h == hashes {
                                j = k;
                                break 'close;
                            }
                        }
                        j += 1;
                    }
                    blank(&mut out, start, j);
                    i = j;
                } else {
                    i += 1;
                }
            }
            b'b' if i + 1 < n && b[i + 1] == b'"' => {
                let start = i;
                i += 2;
                while i < n {
                    if b[i] == b'\\' {
                        i += 2;
                    } else if b[i] == b'"' {
                        i += 1;
                        break;
                    } else {
                        i += 1;
                    }
                }
                blank(&mut out, start, i);
            }
            b'\'' => {
                // Char literal vs lifetime: '\x' escapes and 'c' (single
                // char then closing quote) are literals; anything else —
                // `'a` in `<'a>`, `&'static` — is a lifetime, left alone.
                if i + 1 < n && b[i + 1] == b'\\' {
                    let start = i;
                    let mut j = i + 2;
                    while j < n && b[j] != b'\'' {
                        j += 1;
                    }
                    blank(&mut out, start, (j + 1).min(n));
                    i = (j + 1).min(n);
                } else if i + 2 < n && b[i + 2] == b'\'' {
                    blank(&mut out, i, i + 3);
                    i += 3;
                } else {
                    i += 1;
                }
            }
            _ => i += 1,
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Per-line mask: `true` where the line belongs to a `#[cfg(test)]` item
/// (attribute line through the item's closing brace / terminating `;`).
/// Operates on the blanked source so braces in strings don't confuse the
/// matcher.
fn test_mask(blanked: &str) -> Vec<bool> {
    let lines: Vec<&str> = blanked.lines().collect();
    let mut mask = vec![false; lines.len()];
    let mut li = 0;
    while li < lines.len() {
        if !lines[li].trim_start().starts_with("#[cfg(test)]") {
            li += 1;
            continue;
        }
        let mut depth = 0i64;
        let mut opened = false;
        let mut lj = li;
        'item: while lj < lines.len() {
            for ch in lines[lj].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            break 'item;
                        }
                    }
                    // `#[cfg(test)] mod tests;` / `use ...;` — braceless item.
                    ';' if !opened && lj > li => break 'item,
                    _ => {}
                }
            }
            lj += 1;
        }
        for m in mask.iter_mut().take((lj + 1).min(lines.len())).skip(li) {
            *m = true;
        }
        li = lj + 1;
    }
    mask
}

fn is_ident(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Positions of word-bounded occurrences of `word` in `line`.
fn word_positions(line: &str, word: &str) -> Vec<usize> {
    let lb = line.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = line[from..].find(word) {
        let at = from + p;
        let pre_ok = at == 0 || !is_ident(lb[at - 1]);
        let end = at + word.len();
        let post_ok = end >= lb.len() || !is_ident(lb[end]);
        if pre_ok && post_ok {
            out.push(at);
        }
        from = at + word.len().max(1);
    }
    out
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

/// no-panic-serving-path over one file.
fn rule_no_panic(rel: &str, raw: &[&str], code: &[&str], tests: &[bool], out: &mut Vec<Finding>) {
    if !in_serving_scope(rel) {
        return;
    }
    let index_scope = in_indexing_scope(rel);
    for (i, &line) in code.iter().enumerate() {
        if tests[i] {
            continue;
        }
        let mut hits: Vec<String> = Vec::new();
        if line.contains(".unwrap()") {
            hits.push("`.unwrap()`".to_string());
        }
        if line.contains(".expect(") {
            hits.push("`.expect(`".to_string());
        }
        for mac in ["panic", "unreachable", "todo", "unimplemented"] {
            let call = format!("{mac}!");
            if line
                .find(&call)
                .is_some_and(|p| p == 0 || !is_ident(line.as_bytes()[p - 1]))
            {
                hits.push(format!("`{call}`"));
            }
        }
        for what in hits {
            out.push(Finding {
                rule: RULE_PANIC,
                file: rel.to_string(),
                line: i + 1,
                msg: format!("{what} in serving path (shed or return an error instead)"),
                snippet: raw[i].to_string(),
            });
        }
        if index_scope && !line.trim_start().starts_with('#') {
            let lb = line.as_bytes();
            for (p, &c) in lb.iter().enumerate() {
                if c == b'[' && p > 0 {
                    let prev = lb[p - 1];
                    if is_ident(prev) || prev == b')' || prev == b']' {
                        out.push(Finding {
                            rule: RULE_PANIC,
                            file: rel.to_string(),
                            line: i + 1,
                            msg: "unchecked indexing in serving path (use `get`/`get_mut` \
                                  or add an audited allow)"
                                .to_string(),
                            snippet: raw[i].to_string(),
                        });
                        break; // one finding per line
                    }
                }
            }
        }
    }
}

/// sync-via-shim over one file (tests included: models must use the shim
/// too, or the checker can't interpose).
fn rule_sync_shim(rel: &str, raw: &[&str], code: &[&str], out: &mut Vec<Finding>) {
    if in_shim(rel) {
        return;
    }
    for (i, &line) in code.iter().enumerate() {
        if line.contains("std::sync::") && (line.contains("Mutex") || line.contains("Condvar")) {
            out.push(Finding {
                rule: RULE_SYNC,
                file: rel.to_string(),
                line: i + 1,
                msg: "direct std::sync Mutex/Condvar (use crate::util::sync so the model \
                      checker can interpose)"
                    .to_string(),
                snippet: raw[i].to_string(),
            });
        }
    }
}

/// no-unbounded-retry over one file: a loop header in `coordinator/`
/// non-test code that names retry work must make its bound visible on the
/// same line. Heuristic by design (the scanner has no CFG): it catches
/// the common shapes — `while needs_retry {`, `for attempt in 0.. {` —
/// and anything subtler must either hoist the bound into the header
/// (`for attempt in 0..MAX_REQUEST_ATTEMPTS`) or carry an audited allow.
fn rule_no_unbounded_retry(
    rel: &str,
    raw: &[&str],
    code: &[&str],
    tests: &[bool],
    out: &mut Vec<Finding>,
) {
    if !rel.starts_with("coordinator/") {
        return;
    }
    const TRIGGERS: &[&str] = &["retry", "retries", "attempt", "respawn", "restart"];
    const BOUNDS: &[&str] = &["max", "budget", "cap", "limit"];
    for (i, &line) in code.iter().enumerate() {
        if tests[i] {
            continue;
        }
        let t = line.trim_start();
        let is_header = ["loop", "while", "for"].iter().any(|kw| {
            t.starts_with(kw) && t.as_bytes().get(kw.len()).is_none_or(|&c| !is_ident(c))
        });
        if !is_header {
            continue;
        }
        let low = t.to_ascii_lowercase();
        if TRIGGERS.iter().any(|w| low.contains(w)) && !BOUNDS.iter().any(|w| low.contains(w)) {
            out.push(Finding {
                rule: RULE_RETRY,
                file: rel.to_string(),
                line: i + 1,
                msg: "retry loop without a visible bound (reference the budget/cap/max \
                      constant in the loop header, or add an audited allow)"
                    .to_string(),
                snippet: raw[i].to_string(),
            });
        }
    }
}

/// no-undocumented-unsafe over one file.
fn rule_undocumented_unsafe(rel: &str, raw: &[&str], code: &[&str], out: &mut Vec<Finding>) {
    for (i, &line) in code.iter().enumerate() {
        if word_positions(line, "unsafe").is_empty() {
            continue;
        }
        let lo = i.saturating_sub(10);
        let documented = raw[lo..=i].iter().any(|l| l.contains("SAFETY:"));
        if !documented {
            out.push(Finding {
                rule: RULE_UNSAFE,
                file: rel.to_string(),
                line: i + 1,
                msg: "`unsafe` without a `// SAFETY:` comment in the preceding 10 lines"
                    .to_string(),
                snippet: raw[i].to_string(),
            });
        }
    }
}

/// All per-file rules over one source file.
fn scan_source(rel: &str, src: &str) -> Vec<Finding> {
    let blanked = blank_noncode(src);
    let raw: Vec<&str> = src.lines().collect();
    let code: Vec<&str> = blanked.lines().collect();
    let tests = test_mask(&blanked);
    let mut out = Vec::new();
    rule_no_panic(rel, &raw, &code, &tests, &mut out);
    rule_sync_shim(rel, &raw, &code, &mut out);
    rule_no_unbounded_retry(rel, &raw, &code, &tests, &mut out);
    rule_undocumented_unsafe(rel, &raw, &code, &mut out);
    out
}

/// Module names carrying `#[allow(missing_docs)]` in `lib.rs` (the
/// attribute line immediately followed by `pub mod <name>;`).
fn lib_missing_docs_allows(lib_src: &str) -> Vec<String> {
    let blanked = blank_noncode(lib_src);
    let lines: Vec<&str> = blanked.lines().collect();
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if line.trim() != "#[allow(missing_docs)]" {
            continue;
        }
        if let Some(next) = lines.get(i + 1) {
            let t = next.trim();
            if let Some(rest) = t.strip_prefix("pub mod ") {
                if let Some(name) = rest.strip_suffix(';') {
                    out.push(name.trim().to_string());
                }
            }
        }
    }
    out
}

/// missing-docs-inventory: compare lib.rs allows against the audited list.
/// Returns (errors, warnings).
fn check_docs_inventory(lib_src: &str, allowed: &[String]) -> (Vec<Finding>, Vec<String>) {
    let present = lib_missing_docs_allows(lib_src);
    let mut errors = Vec::new();
    for m in &present {
        if !allowed.contains(m) {
            errors.push(Finding {
                rule: RULE_DOCS,
                file: "lib.rs".to_string(),
                line: 0,
                msg: format!(
                    "new `#[allow(missing_docs)]` on module `{m}` — docs-debt regression \
                     (document the module or add it to missing_docs_allowed with a plan)"
                ),
                snippet: format!("pub mod {m};"),
            });
        }
    }
    let mut warnings = Vec::new();
    for m in allowed {
        if !present.contains(m) {
            warnings.push(format!(
                "lint_allow.toml: missing_docs_allowed entry `{m}` is stale (module is now \
                 documented) — remove it"
            ));
        }
    }
    (errors, warnings)
}

// ---------------------------------------------------------------------------
// Allowlist (minimal TOML subset: [[allow]] tables of string keys, plus one
// top-level string array)
// ---------------------------------------------------------------------------

#[derive(Debug, Default, Clone)]
struct AllowEntry {
    rule: String,
    file: String,
    contains: String,
    why: String,
}

#[derive(Debug, Default)]
struct AllowList {
    entries: Vec<AllowEntry>,
    missing_docs_allowed: Vec<String>,
}

/// Extract the quoted strings from a `["a", "b"]` literal.
fn parse_string_array(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = s;
    while let Some(start) = rest.find('"') {
        let Some(len) = rest[start + 1..].find('"') else { break };
        out.push(rest[start + 1..start + 1 + len].to_string());
        rest = &rest[start + 1 + len + 1..];
    }
    out
}

fn parse_allow_toml(text: &str) -> Result<AllowList> {
    let mut list = AllowList::default();
    let mut current: Option<AllowEntry> = None;
    for (ln, raw_line) in text.lines().enumerate() {
        let line = raw_line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[allow]]" {
            if let Some(e) = current.take() {
                list.entries.push(e);
            }
            current = Some(AllowEntry::default());
            continue;
        }
        let Some((key, val)) = line.split_once('=') else {
            bail!("lint_allow.toml:{}: expected `key = value`", ln + 1);
        };
        let (key, val) = (key.trim(), val.trim());
        if key == "missing_docs_allowed" {
            list.missing_docs_allowed = parse_string_array(val);
            continue;
        }
        let Some(e) = current.as_mut() else {
            bail!("lint_allow.toml:{}: key `{key}` outside an [[allow]] table", ln + 1);
        };
        let Some(v) = val.strip_prefix('"').and_then(|v| v.strip_suffix('"')) else {
            bail!("lint_allow.toml:{}: `{key}` must be a double-quoted string", ln + 1);
        };
        match key {
            "rule" => e.rule = v.to_string(),
            "file" => e.file = v.to_string(),
            "contains" => e.contains = v.to_string(),
            "why" => e.why = v.to_string(),
            other => bail!("lint_allow.toml:{}: unknown key `{other}`", ln + 1),
        }
    }
    if let Some(e) = current.take() {
        list.entries.push(e);
    }
    for (i, e) in list.entries.iter().enumerate() {
        if e.rule.is_empty() || e.file.is_empty() || e.contains.is_empty() || e.why.is_empty() {
            bail!("lint_allow.toml: [[allow]] entry {} needs rule, file, contains and why", i + 1);
        }
    }
    Ok(list)
}

/// Partition findings into (kept, suppressed); flags which entries matched.
fn apply_allows(findings: Vec<Finding>, allows: &[AllowEntry]) -> (Vec<Finding>, Vec<bool>) {
    let mut used = vec![false; allows.len()];
    let kept = findings
        .into_iter()
        .filter(|f| {
            let mut suppressed = false;
            for (i, a) in allows.iter().enumerate() {
                if f.rule == a.rule && f.file.ends_with(&a.file) && f.snippet.contains(&a.contains)
                {
                    used[i] = true;
                    suppressed = true;
                }
            }
            !suppressed
        })
        .collect();
    (kept, used)
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        if path.is_dir() {
            rust_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let src_root = root.join("rust/src");
    let allow_path = root.join("lint_allow.toml");
    let allows = if allow_path.exists() {
        parse_allow_toml(&std::fs::read_to_string(&allow_path)?)?
    } else {
        AllowList::default()
    };

    let mut files = Vec::new();
    rust_files(&src_root, &mut files)?;

    let mut findings = Vec::new();
    let mut lib_src = String::new();
    for path in &files {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = path
            .strip_prefix(&src_root)
            .expect("walked under src_root")
            .to_string_lossy()
            .replace('\\', "/");
        if rel == "lib.rs" {
            lib_src = src.clone();
        }
        findings.extend(scan_source(&rel, &src));
    }

    let (docs_errors, mut warnings) = check_docs_inventory(&lib_src, &allows.missing_docs_allowed);
    findings.extend(docs_errors);

    let (kept, used) = apply_allows(findings, &allows.entries);
    for (i, a) in allows.entries.iter().enumerate() {
        if !used[i] {
            warnings.push(format!(
                "lint_allow.toml: unused [[allow]] entry (rule={}, file={}, contains={:?}) — \
                 the code it audited is gone; remove it",
                a.rule, a.file, a.contains
            ));
        }
    }

    for w in &warnings {
        eprintln!("warning: {w}");
    }
    for f in &kept {
        eprintln!("error[{}]: rust/src/{}:{}: {}", f.rule, f.file, f.line, f.msg);
        eprintln!("    {}", f.snippet.trim());
    }
    let suppressed = allows.entries.iter().zip(&used).filter(|(_, &u)| u).count();
    eprintln!(
        "halo-lint: {} file(s), {} error(s), {} warning(s), {} audited allow(s) in use",
        files.len(),
        kept.len(),
        warnings.len(),
        suppressed
    );
    if !kept.is_empty() {
        std::process::exit(1);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fixture tests: every rule must demonstrably fire and demonstrably pass
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn blanking_strips_comments_and_strings_keeps_lines() {
        let src = "let a = \"x.unwrap()\"; // .expect(\nlet b = 'y'; /* panic! */ b\n";
        let out = blank_noncode(src);
        assert_eq!(out.lines().count(), src.lines().count());
        assert!(!out.contains(".unwrap()"));
        assert!(!out.contains(".expect("));
        assert!(!out.contains("panic!"));
        assert!(out.contains("let a"));
        assert!(out.contains("let b"));
    }

    #[test]
    fn blanking_handles_raw_strings_and_lifetimes() {
        let src = "let r = r#\"a \"quoted\" .unwrap()\"#;\nfn f<'a>(x: &'a str) {}\n";
        let out = blank_noncode(src);
        assert!(!out.contains(".unwrap()"));
        assert!(out.contains("fn f<'a>(x: &'a str)"), "lifetimes must survive: {out}");
    }

    #[test]
    fn panic_rule_fires_on_each_pattern() {
        for bad in [
            "let x = m.lock().unwrap();",
            "let x = rx.recv().expect(\"closed\");",
            "panic!(\"boom\");",
            "unreachable!()",
            "todo!()",
            "unimplemented!()",
        ] {
            let f = scan_source("coordinator/server.rs", bad);
            assert_eq!(rules_of(&f), vec![RULE_PANIC], "pattern: {bad}");
        }
    }

    #[test]
    fn panic_rule_scope_and_lookalikes() {
        // Outside the serving path: clean.
        assert!(scan_source("mac/gate.rs", "x.unwrap();").is_empty());
        // Poison-absorbing recovery is not unwrap.
        let ok = "let g = m.lock().unwrap_or_else(|e| e.into_inner());";
        assert!(scan_source("coordinator/server.rs", ok).is_empty());
        // `panic_any` is not the macro.
        assert!(scan_source("coordinator/server.rs", "std::panic::panic_any(Abort);").is_empty());
    }

    #[test]
    fn panic_rule_skips_cfg_test_items() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        assert!(scan_source("coordinator/batch.rs", src).is_empty());
        // ...but the same call outside the test module still fires.
        let src2 = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests {}\n";
        assert_eq!(rules_of(&scan_source("coordinator/batch.rs", src2)), vec![RULE_PANIC]);
    }

    #[test]
    fn indexing_flagged_in_coordinator_only() {
        let idx = "let y = xs[i];";
        assert_eq!(rules_of(&scan_source("coordinator/server.rs", idx)), vec![RULE_PANIC]);
        let slice = "let t = &p[p.len() - n..];";
        assert_eq!(rules_of(&scan_source("coordinator/server.rs", slice)), vec![RULE_PANIC]);
        // Kernel files: unwrap/panic rules apply, indexing does not (Miri covers them).
        assert!(scan_source("runtime/qkernels.rs", idx).is_empty());
        assert_eq!(
            rules_of(&scan_source("runtime/qkernels.rs", "x.unwrap();")),
            vec![RULE_PANIC]
        );
        // vec![...] and attributes are not indexing.
        assert!(scan_source("coordinator/server.rs", "let v = vec![1, 2];").is_empty());
        assert!(scan_source("coordinator/server.rs", "#[derive(Debug)]\nstruct S;").is_empty());
        // Array types/literals: `[` preceded by space or `&` — clean.
        assert!(scan_source("coordinator/server.rs", "let a: [u8; 4] = [0; 4];").is_empty());
    }

    #[test]
    fn sync_rule_fires_outside_shim_only() {
        let direct = "use std::sync::Mutex;";
        assert_eq!(rules_of(&scan_source("coordinator/metrics.rs", direct)), vec![RULE_SYNC]);
        assert_eq!(
            rules_of(&scan_source("mac/profile.rs", "let c = std::sync::Condvar::new();")),
            vec![RULE_SYNC]
        );
        // The shim itself is the one place that may touch std::sync.
        assert!(scan_source("util/sync/primitives.rs", direct).is_empty());
        // Non-Mutex std::sync (mpsc, Arc, OnceLock) is fine anywhere.
        assert!(scan_source("coordinator/server.rs", "use std::sync::mpsc;").is_empty());
        assert!(scan_source("mac/profile.rs", "use std::sync::OnceLock;").is_empty());
        // The shim's own re-export path is fine.
        assert!(scan_source("coordinator/server.rs", "use crate::util::sync::Mutex;").is_empty());
    }

    #[test]
    fn retry_rule_requires_bound_on_loop_header() {
        // Unbounded-looking retry loops fire...
        let bad = "while needs_retry { attempt(); }";
        assert_eq!(rules_of(&scan_source("coordinator/server.rs", bad)), vec![RULE_RETRY]);
        let bad2 = "for attempt in 0.. { respawn(); }";
        assert_eq!(rules_of(&scan_source("coordinator/server.rs", bad2)), vec![RULE_RETRY]);
        // ...while a bound named in the header passes.
        let good = "while attempts < cfg.max_request_attempts { go(); }";
        assert!(scan_source("coordinator/server.rs", good).is_empty());
        let good2 = "for attempt in 0..RETRY_BUDGET { go(); }";
        assert!(scan_source("coordinator/server.rs", good2).is_empty());
        // Non-retry loops and non-header retry mentions don't fire.
        assert!(scan_source("coordinator/server.rs", "for req in incoming { go(); }").is_empty());
        assert!(scan_source("coordinator/server.rs", "let respawn = true;").is_empty());
        // `loop_`-prefixed identifiers are not loop headers.
        assert!(scan_source("coordinator/server.rs", "loop_retry.tick();").is_empty());
        // Comments are blanked, so a retry note on a plain loop is clean.
        assert!(scan_source("coordinator/server.rs", "loop { // retry forever\n}").is_empty());
        // Scope: coordinator/ non-test code only.
        assert!(scan_source("runtime/sim.rs", bad).is_empty());
        let in_test = "#[cfg(test)]\nmod tests {\n    fn f() { while needs_retry {} }\n}\n";
        assert!(scan_source("coordinator/server.rs", in_test).is_empty());
        // Allowlistable like every other rule.
        let allows = vec![AllowEntry {
            rule: RULE_RETRY.to_string(),
            file: "coordinator/server.rs".to_string(),
            contains: "needs_retry".to_string(),
            why: "bounded by the supervisor's death counter one frame up".to_string(),
        }];
        let (kept, used) = apply_allows(scan_source("coordinator/server.rs", bad), &allows);
        assert!(kept.is_empty());
        assert_eq!(used, vec![true]);
    }

    #[test]
    fn unsafe_rule_requires_nearby_safety_comment() {
        let bad = "let p = unsafe { std::slice::from_raw_parts(a, n) };";
        assert_eq!(rules_of(&scan_source("runtime/xla.rs", bad)), vec![RULE_UNSAFE]);
        let good = "// SAFETY: same layout, bounded lifetime.\n\
                    let p = unsafe { std::slice::from_raw_parts(a, n) };";
        assert!(scan_source("runtime/xla.rs", good).is_empty());
        // Identifiers containing the word are not the keyword...
        assert!(scan_source("runtime/xla.rs", "#[allow(unsafe_code)]\nfn f() {}").is_empty());
        // ...and AssertUnwindSafe is not unsafe.
        assert!(scan_source(
            "coordinator/server.rs",
            "let r = catch_unwind(AssertUnwindSafe(f));"
        )
        .is_empty());
    }

    #[test]
    fn docs_inventory_detects_regression_and_staleness() {
        let lib = "#[allow(missing_docs)]\npub mod experiments;\npub mod quant;\n";
        // In the audited list: clean.
        let (errs, warns) = check_docs_inventory(lib, &["experiments".to_string()]);
        assert!(errs.is_empty() && warns.is_empty());
        // Not in the list: docs-debt regression.
        let (errs, _) = check_docs_inventory(lib, &[]);
        assert_eq!(rules_of(&errs), vec![RULE_DOCS]);
        // Listed but no longer present: stale warning, no error.
        let (errs, warns) =
            check_docs_inventory("pub mod quant;\n", &["experiments".to_string()]);
        assert!(errs.is_empty());
        assert_eq!(warns.len(), 1);
    }

    #[test]
    fn allowlist_suppresses_matches_and_reports_unused() {
        let findings = scan_source("coordinator/server.rs", "let s = &self.shards[s];");
        assert_eq!(findings.len(), 1);
        let allows = vec![
            AllowEntry {
                rule: RULE_PANIC.to_string(),
                file: "coordinator/server.rs".to_string(),
                contains: "self.shards[s]".to_string(),
                why: "s from 0..shards.len()".to_string(),
            },
            AllowEntry {
                rule: RULE_PANIC.to_string(),
                file: "coordinator/server.rs".to_string(),
                contains: "never-matches".to_string(),
                why: "stale".to_string(),
            },
        ];
        let (kept, used) = apply_allows(findings, &allows);
        assert!(kept.is_empty());
        assert_eq!(used, vec![true, false]);
        // Wrong rule never suppresses.
        let f2 = scan_source("coordinator/server.rs", "use std::sync::Mutex;");
        let (kept2, _) = apply_allows(f2, &allows);
        assert_eq!(kept2.len(), 1);
    }

    #[test]
    fn allow_toml_parses_entries_and_inventory() {
        let text = "# comment\n\
                    missing_docs_allowed = [\"experiments\", \"gpu\"]\n\
                    \n\
                    [[allow]]\n\
                    rule = \"no-panic-serving-path\"\n\
                    file = \"coordinator/server.rs\"\n\
                    contains = \"live[i]\"\n\
                    why = \"i < live.len() loop bound\"\n";
        let list = parse_allow_toml(text).unwrap();
        assert_eq!(list.missing_docs_allowed, vec!["experiments", "gpu"]);
        assert_eq!(list.entries.len(), 1);
        assert_eq!(list.entries[0].contains, "live[i]");
        // Incomplete entries are a hard error, not a silent no-op.
        assert!(parse_allow_toml("[[allow]]\nrule = \"x\"\n").is_err());
    }

    #[test]
    fn clean_tree_fixture_passes_all_rules() {
        let src = "use crate::util::sync::{Arc, Mutex};\n\
                   /// Documented.\n\
                   pub fn serve(m: &Mutex<u32>) -> u32 {\n\
                       *m.lock().unwrap_or_else(|e| e.into_inner())\n\
                   }\n";
        assert!(scan_source("coordinator/server.rs", src).is_empty());
    }
}
