//! `bench_check` — the CI bench-regression gate.
//!
//! Compares a freshly measured bench JSON against a committed baseline and
//! fails (exit 1) when any gated metric regressed beyond the tolerance:
//!
//! ```sh
//! bench_check --baseline BENCH_PR2.json --current /tmp/bench.json \
//!             [--tol 0.30] [--keys matmul.nn.speedup,forward_pass.speedup] \
//!             [--min decode_cached_speedup=2.0]
//! ```
//!
//! Gated metrics are **dimensionless ratios** (speedups, shard-scaling
//! factors), not absolute seconds — absolute timings vary wildly across
//! runner generations, but "the blocked kernel is N× the naive oracle" and
//! "N shards are M× one shard" are portable. A metric passes when
//! `current >= baseline * (1 - tol)`; running *faster* than baseline is
//! never an error. Keys default to every `speedup`/`scaling_*` leaf found
//! in the baseline, so new bench sections are gated automatically once
//! they land in the committed file.
//!
//! `--min key=value` (repeatable) additionally enforces an **absolute
//! floor** on a current-run metric, independent of the committed
//! baseline — for acceptance bars stated as hard numbers rather than
//! regressions. PR 5's documented floor: KV-cached decode holds ≥ 2× the
//! full-recompute throughput at prefix length 256
//! (`--min decode_cached_speedup=2.0` against BENCH_PR5.json).

use std::process::ExitCode;

use halo::util::cli::Args;
use halo::util::Json;

fn main() -> ExitCode {
    let args = Args::from_env();
    match run(&args) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("bench_check: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &Args) -> anyhow::Result<bool> {
    let baseline_path = args.require("baseline")?;
    let current_path = args.require("current")?;
    let tol = args.f64_or("tol", 0.30)?;
    anyhow::ensure!((0.0..1.0).contains(&tol), "--tol must be in [0, 1)");

    let baseline = Json::parse(&std::fs::read_to_string(baseline_path)?)?;
    let current = Json::parse(&std::fs::read_to_string(current_path)?)?;

    let keys: Vec<String> = match args.get("keys") {
        Some(s) => s.split(',').map(|k| k.trim().to_string()).collect(),
        None => ratio_keys(&baseline),
    };
    let mins = parse_mins(&args.get_all("min"))?;
    anyhow::ensure!(
        !keys.is_empty() || !mins.is_empty(),
        "no gated keys (baseline has no ratio leaves and no --min floors)"
    );

    let mut ok = true;
    for key in &keys {
        let base = match lookup(&baseline, key).and_then(|j| j.as_f64().ok()) {
            Some(b) => b,
            None => {
                eprintln!("FAIL {key}: missing or non-numeric in baseline {baseline_path}");
                ok = false;
                continue;
            }
        };
        let cur = match lookup(&current, key).and_then(|j| j.as_f64().ok()) {
            Some(c) => c,
            None => {
                eprintln!("FAIL {key}: missing in current {current_path} (baseline {base:.2})");
                ok = false;
                continue;
            }
        };
        let floor = base * (1.0 - tol);
        if cur >= floor {
            println!("ok   {key}: {cur:.2} (baseline {base:.2}, floor {floor:.2})");
        } else {
            eprintln!("FAIL {key}: {cur:.2} < floor {floor:.2} (baseline {base:.2}, tol {tol})");
            ok = false;
        }
    }
    // Absolute floors: current >= floor, no baseline involved.
    for (key, floor) in &mins {
        match check_min(&current, current_path, key, *floor) {
            Ok(cur) => println!("ok   {key}: {cur:.2} (absolute floor {floor:.2})"),
            Err(msg) => {
                eprintln!("{msg}");
                ok = false;
            }
        }
    }
    if ok {
        println!(
            "bench_check: {} gated metric(s) within tolerance {tol}, {} absolute floor(s) held",
            keys.len(),
            mins.len()
        );
    }
    Ok(ok)
}

/// Check one `--min` absolute floor against the current report. `Err`
/// carries the exact FAIL line `run` prints — it names the key in every
/// failure mode, so a typo'd or renamed bench key (the key simply absent
/// from the current JSON) fails loudly instead of silently passing.
fn check_min(current: &Json, current_path: &str, key: &str, floor: f64) -> Result<f64, String> {
    match lookup(current, key).and_then(|j| j.as_f64().ok()) {
        Some(cur) if cur >= floor => Ok(cur),
        Some(cur) => Err(format!("FAIL {key}: {cur:.2} < absolute floor {floor:.2}")),
        None => Err(format!("FAIL {key}: missing in current {current_path} (floor {floor:.2})")),
    }
}

/// Parse repeated `--min key=value` floors.
fn parse_mins(specs: &[&str]) -> anyhow::Result<Vec<(String, f64)>> {
    specs
        .iter()
        .map(|s| {
            let (key, val) = s
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--min expects key=value, got `{s}`"))?;
            let floor: f64 = val
                .parse()
                .map_err(|_| anyhow::anyhow!("--min {key}: `{val}` is not a number"))?;
            Ok((key.trim().to_string(), floor))
        })
        .collect()
}

/// Dotted-path lookup: `matmul.nn.speedup`.
fn lookup<'a>(j: &'a Json, path: &str) -> Option<&'a Json> {
    let mut cur = j;
    for part in path.split('.') {
        cur = cur.get(part)?;
    }
    Some(cur)
}

/// Every dimensionless-ratio leaf, in sorted order: keys named `speedup`
/// or ending in `_speedup` / `_ratio` / `_saving`, or starting with
/// `scaling`. BENCH_PR2 contributes `speedup` leaves, BENCH_PR3
/// `scaling_throughput`, BENCH_PR4 `throughput_ratio` / `bytes_saving` /
/// `modeled_speedup` — all gated automatically once committed.
fn ratio_keys(j: &Json) -> Vec<String> {
    let mut out = Vec::new();
    walk(j, String::new(), &mut out);
    out.sort();
    out
}

fn is_ratio_key(k: &str) -> bool {
    k == "speedup"
        || k.starts_with("scaling")
        || k.ends_with("_speedup")
        || k.ends_with("_ratio")
        || k.ends_with("_saving")
}

fn walk(j: &Json, prefix: String, out: &mut Vec<String>) {
    if let Json::Obj(m) = j {
        for (k, v) in m {
            let path = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
            if matches!(v, Json::Num(_)) && is_ratio_key(k) {
                out.push(path);
            } else {
                walk(v, path, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn j(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    #[test]
    fn ratio_keys_found_recursively() {
        let b = j(r#"{"matmul":{"nn":{"speedup":3.0,"naive_s":1.0}},
                      "scaling_throughput":2.5,"smoke":true}"#);
        assert_eq!(ratio_keys(&b), vec!["matmul.nn.speedup", "scaling_throughput"]);
    }

    #[test]
    fn ratio_keys_cover_pr4_metrics() {
        // The BENCH_PR4 leaves must be auto-gated when no --keys are given.
        let b = j(r#"{"layer":{"throughput_ratio":0.8,"quant_ms":2.0},
                      "memory":{"bytes_saving":3.6,"packed_bytes":1000},
                      "model_cost":{"modeled_speedup":1.3,"sparse_nnz":4}}"#);
        assert_eq!(
            ratio_keys(&b),
            vec![
                "layer.throughput_ratio",
                "memory.bytes_saving",
                "model_cost.modeled_speedup"
            ]
        );
    }

    #[test]
    fn lookup_dotted_paths() {
        let b = j(r#"{"a":{"b":{"c":1.5}}}"#);
        assert_eq!(lookup(&b, "a.b.c").unwrap().as_f64().unwrap(), 1.5);
        assert!(lookup(&b, "a.x").is_none());
    }

    #[test]
    fn gate_passes_and_fails_on_tolerance() {
        let dir = std::env::temp_dir().join(format!("halo_bench_check_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("base.json");
        let cur = dir.join("cur.json");
        std::fs::write(&base, r#"{"x":{"speedup":4.0}}"#).unwrap();

        let argv = |cur_path: &std::path::Path, tol: &str| {
            Args::parse(
                [
                    "--baseline",
                    base.to_str().unwrap(),
                    "--current",
                    cur_path.to_str().unwrap(),
                    "--tol",
                    tol,
                ]
                .into_iter()
                .map(String::from),
            )
        };

        // Within tolerance (3.0 >= 4.0 * 0.7).
        std::fs::write(&cur, r#"{"x":{"speedup":3.0}}"#).unwrap();
        assert!(run(&argv(&cur, "0.30")).unwrap());
        // Improvement always passes.
        std::fs::write(&cur, r#"{"x":{"speedup":9.0}}"#).unwrap();
        assert!(run(&argv(&cur, "0.30")).unwrap());
        // Regression beyond tolerance fails.
        std::fs::write(&cur, r#"{"x":{"speedup":2.0}}"#).unwrap();
        assert!(!run(&argv(&cur, "0.30")).unwrap());
        // Missing key in current fails.
        std::fs::write(&cur, r#"{"y":1.0}"#).unwrap();
        assert!(!run(&argv(&cur, "0.30")).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn min_floors_parse_and_gate() {
        assert_eq!(
            parse_mins(&["decode_cached_speedup=2.0"]).unwrap(),
            vec![("decode_cached_speedup".to_string(), 2.0)]
        );
        assert!(parse_mins(&["oops"]).is_err());
        assert!(parse_mins(&["k=notanum"]).is_err());

        let dir = std::env::temp_dir().join(format!("halo_bench_min_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("base.json");
        let cur = dir.join("cur.json");
        std::fs::write(&base, r#"{"decode_cached_speedup":4.0}"#).unwrap();
        let argv = |min: &str| {
            Args::parse(
                [
                    "--baseline",
                    base.to_str().unwrap(),
                    "--current",
                    cur.to_str().unwrap(),
                    "--keys",
                    "decode_cached_speedup",
                    "--min",
                    min,
                ]
                .into_iter()
                .map(String::from),
            )
        };
        // Above both the baseline tolerance and the absolute floor.
        std::fs::write(&cur, r#"{"decode_cached_speedup":3.5}"#).unwrap();
        assert!(run(&argv("decode_cached_speedup=2.0")).unwrap());
        // Within baseline tolerance but below the absolute floor: FAIL.
        std::fs::write(&cur, r#"{"decode_cached_speedup":3.0}"#).unwrap();
        assert!(!run(&argv("decode_cached_speedup=3.2")).unwrap());
        // Missing key fails the floor too.
        std::fs::write(&cur, r#"{"other":1.0}"#).unwrap();
        assert!(!run(&argv("decode_cached_speedup=2.0")).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn min_floor_failure_lines_name_the_key() {
        let cur = j(r#"{"quant_vs_dense_throughput":1.4,"layer":{"throughput_ratio":0.9}}"#);
        // Present and above the floor: passes with the measured value.
        assert_eq!(check_min(&cur, "cur.json", "quant_vs_dense_throughput", 1.0).unwrap(), 1.4);
        assert_eq!(check_min(&cur, "cur.json", "layer.throughput_ratio", 0.5).unwrap(), 0.9);
        // Present but below: the FAIL line names the key and both numbers.
        let msg = check_min(&cur, "cur.json", "quant_vs_dense_throughput", 2.0).unwrap_err();
        assert!(msg.starts_with("FAIL quant_vs_dense_throughput"), "bad line: {msg}");
        assert!(msg.contains("1.40") && msg.contains("2.00"), "bad line: {msg}");
        // Absent (typo'd or renamed bench key): fails loudly, naming the
        // missing key and the file it was expected in.
        let msg = check_min(&cur, "cur.json", "spec_decode_speedup", 1.0).unwrap_err();
        assert!(msg.starts_with("FAIL spec_decode_speedup"), "bad line: {msg}");
        assert!(msg.contains("missing") && msg.contains("cur.json"), "bad line: {msg}");
        // Non-numeric leaves count as absent, not as silently comparable.
        let cur = j(r#"{"quant_vs_dense_throughput":"fast"}"#);
        assert!(check_min(&cur, "cur.json", "quant_vs_dense_throughput", 1.0).is_err());
    }

    #[test]
    fn min_floor_on_absent_key_fails_even_when_ratio_keys_pass() {
        // A --min floor on a key the --keys gate never looks at must still
        // fail the run when the key is absent from the current JSON.
        let dir = std::env::temp_dir().join(format!("halo_bench_minonly_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("base.json");
        let cur = dir.join("cur.json");
        std::fs::write(&base, r#"{"x":{"speedup":4.0}}"#).unwrap();
        let argv = || {
            Args::parse(
                [
                    "--baseline",
                    base.to_str().unwrap(),
                    "--current",
                    cur.to_str().unwrap(),
                    "--keys",
                    "x.speedup",
                    "--min",
                    "quant_vs_dense_throughput=1.0",
                ]
                .into_iter()
                .map(String::from),
            )
        };
        // Ratio key holds but the floor's key is absent: FAIL.
        std::fs::write(&cur, r#"{"x":{"speedup":4.0}}"#).unwrap();
        assert!(!run(&argv()).unwrap());
        // Same run with the key present and above the floor: passes.
        std::fs::write(&cur, r#"{"x":{"speedup":4.0},"quant_vs_dense_throughput":1.4}"#).unwrap();
        assert!(run(&argv()).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }
}
